//! The fit/predict service API: [`Kmeans`] (fluent entry point) and
//! [`FittedModel`] (owned result, applicable to new points).
//!
//! This is the serving-facing surface the ROADMAP's north star asks
//! for: fit once on a shared [`Runtime`], then answer any number of
//! `predict` calls — across datasets, threads, and (via [`save`] /
//! [`load`]) process restarts:
//!
//! ```no_run
//! use eakm::prelude::*;
//!
//! let rt = Runtime::new(4); // one pool for the whole process
//! let data = eakm::data::synth::blobs(100_000, 8, 50, 0.05, 42);
//! let model = Kmeans::new(50)
//!     .algorithm(Algorithm::ExpNs)
//!     .seed(7)
//!     .fit(&rt, &data)
//!     .unwrap();
//! let queries = eakm::data::synth::blobs(1_000, 8, 50, 0.05, 43);
//! let labels = model.predict(&rt, &queries).unwrap();
//! model.save(std::path::Path::new("model.json")).unwrap();
//! # let _ = labels;
//! ```
//!
//! `predict` is a counter-free, pool-sharded nearest-centroid scan on
//! the same blocked `linalg` kernels the fit path uses; every query
//! point is independent, so its output is **bit-identical at any
//! runtime width**.
//!
//! [`save`]: FittedModel::save
//! [`load`]: FittedModel::load

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::algorithms::common::nearest_labels;
use crate::algorithms::Algorithm;
use crate::config::RunConfig;
use crate::coordinator::Runner;
use crate::data::{BlockCursor, DataSource, RowBlock, SliceCursor};
use crate::error::{EakmError, Result};
use crate::init::InitMethod;
use crate::json::Json;
use crate::linalg::{sqdist, sqnorms_rows};
use crate::metrics::{BatchTelemetry, Counters, PhaseTimes, RunReport, SchedTelemetry};
use crate::obs::FitObserver;
use crate::runtime::Runtime;

/// Model-file format marker and version.
const MODEL_FORMAT: &str = "eakm-fitted-model";
const MODEL_VERSION: usize = 1;

/// Fluent configuration for a clustering fit.
///
/// A thin builder over [`RunConfig`] that resolves to the service API:
/// `fit` returns an owned [`FittedModel`] instead of borrowing anything
/// from the training data. Thread count comes from the [`Runtime`]
/// passed at fit time, not from the builder.
#[derive(Clone, Debug)]
pub struct Kmeans {
    cfg: RunConfig,
}

impl Kmeans {
    /// Start configuring a `k`-cluster fit (algorithm defaults to
    /// `Auto`: resolved by dimension at fit time).
    pub fn new(k: usize) -> Self {
        Kmeans {
            cfg: RunConfig::new(Algorithm::Auto, k),
        }
    }

    /// Adopt a fully-specified [`RunConfig`] (CLI / config-file path).
    pub fn from_config(cfg: RunConfig) -> Self {
        Kmeans { cfg }
    }

    /// Which algorithm to run (paper notation; all are exact).
    pub fn algorithm(mut self, algorithm: Algorithm) -> Self {
        self.cfg.algorithm = algorithm;
        self
    }

    /// RNG seed for centroid initialisation.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Seeding strategy.
    pub fn init(mut self, init: InitMethod) -> Self {
        self.cfg.init = init;
        self
    }

    /// Hard cap on Lloyd rounds.
    pub fn max_iters(mut self, max_iters: usize) -> Self {
        self.cfg.max_iters = max_iters;
        self
    }

    /// Wall-clock limit for the fit.
    pub fn time_limit(mut self, limit: Duration) -> Self {
        self.cfg.time_limit = Some(limit);
        self
    }

    /// Fit on mini-batches of (initially) `batch_size` sampled rows per
    /// round instead of full scans — the latency-bounded refinement
    /// mode. Sizes covering the whole dataset run the exact full-batch
    /// engine unchanged; see [`batch_growth`](Kmeans::batch_growth) for
    /// the schedule.
    pub fn batch_size(mut self, batch_size: usize) -> Self {
        self.cfg.batch_size = Some(batch_size);
        self
    }

    /// Mini-batch growth factor per round: > 1 grows a *nested* batch
    /// (doubling = 2.0, Newling & Fleuret 2016b) until it covers the
    /// dataset; exactly 1 redraws a fresh batch each round (Sculley
    /// style). Only meaningful together with
    /// [`batch_size`](Kmeans::batch_size).
    pub fn batch_growth(mut self, batch_growth: f64) -> Self {
        self.cfg.batch_growth = batch_growth;
        self
    }

    /// Shards in the over-decomposed scan plan
    /// ([`AUTO_SCAN_SHARDS`](crate::coordinator::sched::AUTO_SCAN_SHARDS)
    /// = 0 derives the count from `n`). A scheduling knob only: the
    /// fitted model is bit-identical at any value.
    pub fn scan_shards(mut self, scan_shards: usize) -> Self {
        self.cfg.scan_shards = scan_shards;
        self
    }

    /// The underlying run configuration.
    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Cluster `data` to convergence on the shared runtime and return
    /// an owned model.
    pub fn fit(&self, rt: &Runtime, data: &dyn DataSource) -> Result<FittedModel> {
        self.fit_observed(rt, data, None)
    }

    /// [`fit`](Kmeans::fit) with an optional
    /// [`FitObserver`](crate::obs::FitObserver): each round pushes a
    /// structured event into the observer's ring (and, in progress
    /// mode, one stderr line). The fitted model is bit-identical with
    /// or without an observer.
    pub fn fit_observed(
        &self,
        rt: &Runtime,
        data: &dyn DataSource,
        observer: Option<Arc<FitObserver>>,
    ) -> Result<FittedModel> {
        let mut runner = Runner::new(&self.cfg);
        if let Some(obs) = observer {
            runner = runner.with_observer(obs);
        }
        let out = runner.run_on(rt, data)?;
        Ok(FittedModel::from_parts(out.centroids, data.d(), out.report))
    }

    /// Fit, returning the model together with the training labels. The
    /// labels come from the fit's final assignment round (no extra
    /// scan); on a converged fit they equal `predict` on the training
    /// data up to exact distance ties.
    pub fn fit_predict(
        &self,
        rt: &Runtime,
        data: &dyn DataSource,
    ) -> Result<(FittedModel, Vec<u32>)> {
        let out = Runner::new(&self.cfg).run_on(rt, data)?;
        let labels = out.assignments;
        let model = FittedModel::from_parts(out.centroids, data.d(), out.report);
        Ok((model, labels))
    }
}

/// An owned, fitted clustering model: final centroids plus the fit's
/// telemetry. Independent of the training data's lifetime — keep it,
/// ship it, [`save`](FittedModel::save) it.
#[derive(Clone, Debug)]
pub struct FittedModel {
    k: usize,
    d: usize,
    /// Row-major `k×d` centroids.
    centroids: Vec<f64>,
    /// `‖c(j)‖²`, precomputed for the predict scan.
    cnorms: Vec<f64>,
    report: RunReport,
}

impl FittedModel {
    fn from_parts(centroids: Vec<f64>, d: usize, report: RunReport) -> Self {
        let cnorms = sqnorms_rows(&centroids, d);
        FittedModel {
            k: report.k,
            d,
            centroids,
            cnorms,
            report,
        }
    }

    /// Number of clusters.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Sample dimension the model was fitted on.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Final centroids, row-major `k×d`.
    pub fn centroids(&self) -> &[f64] {
        &self.centroids
    }

    /// Telemetry of the fit that produced this model (loaded models
    /// carry the persisted subset: iterations, convergence, mse, …).
    pub fn report(&self) -> &RunReport {
        &self.report
    }

    /// Algorithm that fitted the model (paper notation).
    pub fn algorithm(&self) -> &str {
        &self.report.algorithm
    }

    /// Nearest-centroid labels for `data`, sharded over the runtime's
    /// pool. Counter-free (serving path), and bit-identical at any
    /// runtime width: each query row is scanned independently with the
    /// same blocked kernel and first-lowest-index tie-breaking.
    pub fn predict(&self, rt: &Runtime, data: &dyn DataSource) -> Result<Vec<u32>> {
        if data.d() != self.d {
            return Err(EakmError::Config(format!(
                "predict: model expects d={}, data has d={}",
                self.d,
                data.d()
            )));
        }
        let mut out = vec![0u32; data.n()];
        nearest_labels(rt.pool(), data, &self.centroids, &self.cnorms, &mut out);
        Ok(out)
    }

    /// Nearest-centroid labels for a raw row-major slice of query rows
    /// (`rows.len()` must be a multiple of the model's `d`). The
    /// serving batcher's entry point: coalesced requests are
    /// concatenated into one slice and scanned as a single pool-sharded
    /// pass.
    ///
    /// Row norms are computed with the same [`sqnorms_rows`] kernel
    /// [`Dataset`](crate::data::Dataset) uses and every row's scan is
    /// independent of its neighbours, so the output is **bit-identical**
    /// to [`predict`](FittedModel::predict) on a dataset holding the
    /// same rows — at any runtime width and under any batching of the
    /// slice. That identity is what lets a server coalesce concurrent
    /// requests without changing a single answer.
    pub fn predict_rows(&self, rt: &Runtime, rows: &[f64]) -> Result<Vec<u32>> {
        if rows.len() % self.d != 0 {
            return Err(EakmError::Config(format!(
                "predict_rows: {} values is not a multiple of d={}",
                rows.len(),
                self.d
            )));
        }
        let source = RowsSource {
            rows,
            sqnorms: sqnorms_rows(rows, self.d),
            d: self.d,
        };
        let mut out = vec![0u32; source.n()];
        nearest_labels(rt.pool(), &source, &self.centroids, &self.cnorms, &mut out);
        Ok(out)
    }

    /// Label an entire [`DataSource`] in blocks of `block_rows`,
    /// calling `emit(lo, labels)` once per block in row order — the
    /// streaming bulk-predict entry point: a multi-GB out-of-core
    /// source is labelled with peak memory proportional to one block,
    /// not the dataset.
    ///
    /// Each block is scanned by the same pool-sharded nearest-centroid
    /// pass as [`predict`](FittedModel::predict), and every row's scan
    /// is independent of its neighbours, so the concatenation of the
    /// emitted blocks is **bit-identical** to a whole-source `predict`
    /// — at any thread width and any block boundary. An `Err` from
    /// `emit` aborts the scan and is returned unchanged (the serving
    /// tier uses this to stop labelling when the peer goes away).
    pub fn predict_blocks<F>(
        &self,
        rt: &Runtime,
        data: &dyn DataSource,
        block_rows: usize,
        mut emit: F,
    ) -> Result<()>
    where
        F: FnMut(usize, &[u32]) -> Result<()>,
    {
        if data.d() != self.d {
            return Err(EakmError::Config(format!(
                "predict_blocks: model expects d={}, data has d={}",
                self.d,
                data.d()
            )));
        }
        let block_rows = block_rows.max(1);
        let n = data.n();
        let mut labels = vec![0u32; block_rows.min(n)];
        let mut lo = 0;
        while lo < n {
            let len = block_rows.min(n - lo);
            let window = WindowSource {
                inner: data,
                lo,
                len,
            };
            let out = &mut labels[..len];
            nearest_labels(rt.pool(), &window, &self.centroids, &self.cnorms, out);
            emit(lo, out)?;
            lo += len;
        }
        Ok(())
    }

    /// Nearest centroid of a single query point: `(label, distance)`.
    /// The one-point serving hot path — no dispatch, no allocation.
    pub fn nearest(&self, point: &[f64]) -> (u32, f64) {
        assert_eq!(point.len(), self.d, "query dimension mismatch");
        let mut best = (0u32, f64::INFINITY);
        for (j, c) in self.centroids.chunks_exact(self.d).enumerate() {
            let dist = sqdist(point, c);
            if dist < best.1 {
                best = (j as u32, dist);
            }
        }
        (best.0, best.1.sqrt())
    }

    /// Serialise to the versioned JSON model format.
    pub fn to_json(&self) -> Json {
        let r = &self.report;
        let mut json = Json::obj()
            .field("format", MODEL_FORMAT)
            .field("version", MODEL_VERSION)
            .field("algorithm", r.algorithm.as_str())
            .field("dataset", r.dataset.as_str())
            .field("k", self.k)
            .field("d", self.d)
            .field("n", r.n)
            // seed is a string: u64 does not fit f64 beyond 2^53
            .field("seed", r.seed.to_string())
            .field("iterations", r.iterations)
            .field("converged", r.converged)
            .field("mse", r.mse)
            .field("threads", r.threads)
            .field("wall_secs", r.wall.as_secs_f64());
        if let Some(b) = &r.batch {
            // mini-batch fits round-trip their schedule, so a reloaded
            // model still tells how it was trained
            json = json
                .field("batch_size", b.batch_size)
                .field("batch_growth", b.growth)
                .field(
                    "batch_schedule",
                    Json::Arr(b.schedule.iter().map(|&s| Json::from(s)).collect()),
                );
        }
        if r.sched.dispatches > 0 {
            // the fit's scheduling record rides along (loaded models
            // still tell how their training scan balanced)
            json = json
                .field("sched_shards", r.sched.shards)
                .field("sched_dispatches", r.sched.dispatches)
                .field("sched_reorders", r.sched.reorders)
                .field("sched_init_max_secs", r.sched.init_max.as_secs_f64())
                .field("sched_init_mean_secs", r.sched.init_mean.as_secs_f64())
                .field("sched_scan_max_secs", r.sched.scan_max.as_secs_f64())
                .field("sched_scan_mean_secs", r.sched.scan_mean.as_secs_f64());
        }
        json.field(
            "centroids",
            Json::Arr(self.centroids.iter().map(|&v| Json::Num(v)).collect()),
        )
    }

    /// Deserialise from the JSON model format, revalidating shape and
    /// finiteness. Centroids round-trip bit-identically, so a loaded
    /// model predicts exactly like the one that was saved.
    pub fn from_json(json: &Json) -> Result<FittedModel> {
        let bad = |what: &str| EakmError::Data(format!("model file: {what}"));
        if json.get("format").and_then(Json::as_str) != Some(MODEL_FORMAT) {
            return Err(bad("not an eakm model (missing format marker)"));
        }
        match json.get("version").and_then(Json::as_usize) {
            Some(MODEL_VERSION) => {}
            Some(v) => return Err(bad(&format!("unsupported version {v}"))),
            None => return Err(bad("missing version")),
        }
        let k = json
            .get("k")
            .and_then(Json::as_usize)
            .filter(|&k| k > 0)
            .ok_or_else(|| bad("missing/invalid k"))?;
        let d = json
            .get("d")
            .and_then(Json::as_usize)
            .filter(|&d| d > 0)
            .ok_or_else(|| bad("missing/invalid d"))?;
        let centroids_json = json
            .get("centroids")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("missing centroids"))?;
        if centroids_json.len() != k * d {
            return Err(bad(&format!(
                "centroids have {} values, expected k×d = {}",
                centroids_json.len(),
                k * d
            )));
        }
        let mut centroids = Vec::with_capacity(k * d);
        for v in centroids_json {
            match v.as_f64() {
                Some(x) if x.is_finite() => centroids.push(x),
                _ => return Err(bad("non-finite centroid value")),
            }
        }
        let seed = json
            .get("seed")
            .and_then(Json::as_str)
            .and_then(|s| s.parse::<u64>().ok())
            .ok_or_else(|| bad("missing/invalid seed"))?;
        // batch fields are optional (full-batch models omit them), but
        // when present they are validated as strictly as the rest
        let batch = match json.get("batch_size") {
            None => None,
            Some(bs) => {
                let batch_size = bs
                    .as_usize()
                    .filter(|&b| b > 0)
                    .ok_or_else(|| bad("invalid batch_size"))?;
                let growth = json
                    .get("batch_growth")
                    .and_then(Json::as_f64)
                    .filter(|g| g.is_finite() && *g >= 1.0)
                    .ok_or_else(|| bad("missing/invalid batch_growth"))?;
                let schedule_json = json
                    .get("batch_schedule")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| bad("missing batch_schedule"))?;
                let mut schedule = Vec::with_capacity(schedule_json.len());
                for v in schedule_json {
                    schedule.push(v.as_usize().ok_or_else(|| bad("invalid batch_schedule entry"))?);
                }
                Some(BatchTelemetry {
                    batch_size,
                    growth,
                    schedule,
                })
            }
        };
        // sched fields are optional (older model files omit them) and
        // degrade to zeros — they are a record, not model state
        let secs = |key: &str| {
            json.get(key)
                .and_then(Json::as_f64)
                .and_then(|w| Duration::try_from_secs_f64(w).ok())
                .unwrap_or(Duration::ZERO)
        };
        let sched = SchedTelemetry {
            shards: json.get("sched_shards").and_then(Json::as_usize).unwrap_or(0),
            dispatches: json
                .get("sched_dispatches")
                .and_then(Json::as_usize)
                .unwrap_or(0) as u64,
            reorders: json
                .get("sched_reorders")
                .and_then(Json::as_usize)
                .unwrap_or(0) as u64,
            init_max: secs("sched_init_max_secs"),
            init_mean: secs("sched_init_mean_secs"),
            scan_max: secs("sched_scan_max_secs"),
            scan_mean: secs("sched_scan_mean_secs"),
        };
        let report = RunReport {
            algorithm: json
                .get("algorithm")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            dataset: json
                .get("dataset")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            k,
            // older model files omit n; 0 disables the derived
            // per-point-per-round rates, nothing else
            n: json.get("n").and_then(Json::as_usize).unwrap_or(0),
            seed,
            iterations: json
                .get("iterations")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            converged: json
                .get("converged")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            mse: json.get("mse").and_then(Json::as_f64).unwrap_or(f64::NAN),
            // try_from: a corrupt wall_secs (e.g. 1e30) must degrade to
            // zero, not panic the load path
            wall: json
                .get("wall_secs")
                .and_then(Json::as_f64)
                .and_then(|w| Duration::try_from_secs_f64(w).ok())
                .unwrap_or(Duration::ZERO),
            threads: json.get("threads").and_then(Json::as_usize).unwrap_or(0),
            phases: PhaseTimes::default(),
            counters: Counters::default(),
            round_times: Vec::new(),
            batch,
            // I/O telemetry is transient — it describes one fit's reads,
            // not the model, so it is not persisted
            io: None,
            sched,
        };
        Ok(FittedModel::from_parts(centroids, d, report))
    }

    /// Persist as JSON at `path` (the serving story: models survive
    /// process restarts).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Load a model previously written by [`FittedModel::save`].
    pub fn load(path: &Path) -> Result<FittedModel> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// Borrowed row-major rows with freshly computed norms — the ephemeral
/// [`DataSource`] behind [`FittedModel::predict_rows`]. Norms come from
/// the same [`sqnorms_rows`] kernel [`Dataset`](crate::data::Dataset)
/// uses, which is what keeps slice predictions bit-identical to dataset
/// predictions.
struct RowsSource<'a> {
    rows: &'a [f64],
    sqnorms: Vec<f64>,
    d: usize,
}

impl DataSource for RowsSource<'_> {
    fn n(&self) -> usize {
        self.sqnorms.len()
    }

    fn d(&self) -> usize {
        self.d
    }

    fn open(&self, lo: usize, len: usize) -> Box<dyn BlockCursor + '_> {
        Box::new(SliceCursor::new(self.rows, &self.sqnorms, self.d, lo, len))
    }
}

/// A `len`-row window `[lo, lo+len)` of another source, presented as a
/// standalone [`DataSource`] (rows re-indexed from 0) behind
/// [`FittedModel::predict_blocks`]. Leases pass straight through to the
/// inner source's cursors — same bytes, same precomputed norms — which
/// is what keeps a windowed scan bit-identical to the same rows scanned
/// in place.
struct WindowSource<'a> {
    inner: &'a dyn DataSource,
    lo: usize,
    len: usize,
}

impl DataSource for WindowSource<'_> {
    fn n(&self) -> usize {
        self.len
    }

    fn d(&self) -> usize {
        self.inner.d()
    }

    fn open(&self, lo: usize, len: usize) -> Box<dyn BlockCursor + '_> {
        debug_assert!(lo + len <= self.len, "window open out of range");
        Box::new(WindowCursor {
            inner: self.inner.open(self.lo + lo, len),
            offset: self.lo,
        })
    }
}

/// Cursor for [`WindowSource`]: window-local indices are shifted by the
/// window offset before reaching the inner cursor, and leased blocks
/// are re-labelled with their window-local `lo`.
struct WindowCursor<'a> {
    inner: Box<dyn BlockCursor + 'a>,
    offset: usize,
}

impl BlockCursor for WindowCursor<'_> {
    fn d(&self) -> usize {
        self.inner.d()
    }

    fn lease(&mut self, lo: usize, len: usize) -> RowBlock<'_> {
        let block = self.inner.lease(self.offset + lo, len);
        RowBlock::new(lo, block.d(), block.rows(), block.sqnorms())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("eakm-model-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn fit_produces_owned_model() {
        let rt = Runtime::serial();
        let ds = blobs(400, 3, 5, 0.1, 4);
        let model = Kmeans::new(5)
            .algorithm(Algorithm::ExpNs)
            .seed(3)
            .fit(&rt, &ds)
            .unwrap();
        assert_eq!(model.k(), 5);
        assert_eq!(model.d(), 3);
        assert_eq!(model.centroids().len(), 15);
        assert_eq!(model.algorithm(), "exp-ns");
        assert!(model.report().converged);
        drop(ds); // the model owns its state — data can go away
        assert!(model.centroids().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn predict_rejects_dimension_mismatch() {
        let rt = Runtime::serial();
        let ds = blobs(100, 4, 3, 0.1, 1);
        let model = Kmeans::new(3).seed(1).fit(&rt, &ds).unwrap();
        let wrong = blobs(10, 5, 2, 0.1, 2);
        assert!(matches!(
            model.predict(&rt, &wrong),
            Err(EakmError::Config(_))
        ));
    }

    #[test]
    fn nearest_matches_predict() {
        let rt = Runtime::serial();
        let ds = blobs(300, 4, 6, 0.2, 5);
        let model = Kmeans::new(6).seed(2).fit(&rt, &ds).unwrap();
        let queries = blobs(40, 4, 6, 0.3, 11);
        let labels = model.predict(&rt, &queries).unwrap();
        for i in 0..queries.n() {
            let (j, dist) = model.nearest(queries.row(i));
            // same winner up to exact FP ties between the two kernels:
            // compare achieved distances, not indices
            let d_pred = sqdist(
                queries.row(i),
                &model.centroids()[labels[i] as usize * 4..(labels[i] as usize + 1) * 4],
            )
            .sqrt();
            assert!((d_pred - dist).abs() <= 1e-9 * (1.0 + dist), "query {i} ({j})");
        }
    }

    #[test]
    fn predict_rows_matches_predict_under_any_batching() {
        let ds = blobs(300, 5, 6, 0.15, 21);
        let queries = blobs(97, 5, 6, 0.25, 22);
        let model = {
            let rt = Runtime::serial();
            Kmeans::new(6).seed(4).fit(&rt, &ds).unwrap()
        };
        for threads in [1usize, 4] {
            let rt = Runtime::new(threads);
            let want = model.predict(&rt, &queries).unwrap();
            // the whole slice in one call…
            let got = model.predict_rows(&rt, queries.raw()).unwrap();
            assert_eq!(got, want, "threads={threads}");
            // …and re-batched into uneven chunks: concatenation of the
            // chunked answers must be bit-identical (the micro-batcher's
            // correctness contract)
            let d = queries.d();
            let mut chunked = Vec::new();
            let mut lo = 0;
            for len in [1usize, 7, 30, 59] {
                let rows = &queries.raw()[lo * d..(lo + len) * d];
                chunked.extend(model.predict_rows(&rt, rows).unwrap());
                lo += len;
            }
            assert_eq!(chunked, want, "threads={threads} (chunked)");
        }
        // empty slice is a valid (empty) batch
        let rt = Runtime::serial();
        assert!(model.predict_rows(&rt, &[]).unwrap().is_empty());
        // ragged slices are a config error
        assert!(matches!(
            model.predict_rows(&rt, &[1.0, 2.0, 3.0]),
            Err(EakmError::Config(_))
        ));
    }

    #[test]
    fn fit_predict_returns_training_labels() {
        let rt = Runtime::serial();
        let ds = blobs(500, 3, 4, 0.1, 9);
        let (model, labels) = Kmeans::new(4)
            .algorithm(Algorithm::Sta)
            .seed(1)
            .fit_predict(&rt, &ds)
            .unwrap();
        assert_eq!(labels.len(), ds.n());
        assert!(model.report().converged);
        // converged sta: labels are exactly the nearest-centroid rule
        let fresh = model.predict(&rt, &ds).unwrap();
        assert_eq!(labels, fresh);
    }

    #[test]
    fn json_roundtrip_is_bit_identical() {
        let rt = Runtime::serial();
        let ds = blobs(250, 6, 7, 0.2, 12);
        let model = Kmeans::new(7)
            .algorithm(Algorithm::SelkNs)
            .seed(99)
            .fit(&rt, &ds)
            .unwrap();
        let path = tmpfile("roundtrip.json");
        model.save(&path).unwrap();
        let back = FittedModel::load(&path).unwrap();
        assert_eq!(back.k(), model.k());
        assert_eq!(back.d(), model.d());
        assert_eq!(back.algorithm(), model.algorithm());
        assert_eq!(back.report().seed, 99);
        assert_eq!(back.report().iterations, model.report().iterations);
        let bits = |c: &[f64]| c.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(back.centroids()), bits(model.centroids()));
        assert_eq!(bits(&back.cnorms), bits(&model.cnorms));
        // the fit's scheduling record rides along
        let sched = model.report().sched;
        assert!(sched.dispatches > 0);
        assert_eq!(back.report().sched.shards, sched.shards);
        assert_eq!(back.report().sched.dispatches, sched.dispatches);
        assert_eq!(back.report().sched.reorders, sched.reorders);
    }

    #[test]
    fn predict_blocks_matches_predict_at_any_boundary_and_width() {
        let serial = Runtime::serial();
        let ds = blobs(503, 4, 7, 0.2, 11);
        let model = Kmeans::new(7).seed(2).fit(&serial, &ds).unwrap();
        let want = model.predict(&serial, &ds).unwrap();
        for threads in [1usize, 4] {
            let rt = Runtime::new(threads);
            // boundaries straddle, divide, and exceed n
            for block in [1usize, 64, 100, 503, 1000] {
                let mut got = Vec::new();
                let mut next_lo = 0usize;
                model
                    .predict_blocks(&rt, &ds, block, |lo, labels| {
                        assert_eq!(lo, next_lo, "blocks must arrive in row order");
                        next_lo += labels.len();
                        got.extend_from_slice(labels);
                        Ok(())
                    })
                    .unwrap();
                assert_eq!(got, want, "threads={threads} block={block}");
            }
        }
    }

    #[test]
    fn predict_blocks_propagates_dim_mismatch_and_emit_errors() {
        let rt = Runtime::serial();
        let ds = blobs(120, 4, 3, 0.2, 5);
        let model = Kmeans::new(3).seed(1).fit(&rt, &ds).unwrap();
        let wrong = blobs(50, 3, 3, 0.2, 1);
        assert!(model
            .predict_blocks(&rt, &wrong, 16, |_, _| Ok(()))
            .is_err());
        // an emit error aborts the scan after the first block
        let mut calls = 0;
        let err = model.predict_blocks(&rt, &ds, 50, |_, _| {
            calls += 1;
            Err(EakmError::Net("peer gone".into()))
        });
        assert!(matches!(err, Err(EakmError::Net(_))));
        assert_eq!(calls, 1);
    }

    #[test]
    fn load_rejects_malformed_models() {
        let cases = [
            ("garbage.json", "not json at all"),
            ("noformat.json", r#"{"k":1}"#),
            (
                "badver.json",
                r#"{"format":"eakm-fitted-model","version":9,"k":1,"d":1,"seed":"0","centroids":[0]}"#,
            ),
            (
                "shape.json",
                r#"{"format":"eakm-fitted-model","version":1,"k":2,"d":2,"seed":"0","centroids":[0,0,0]}"#,
            ),
            (
                "nonfinite.json",
                r#"{"format":"eakm-fitted-model","version":1,"k":1,"d":1,"seed":"0","centroids":[null]}"#,
            ),
            // batch_size without a valid batch_growth must fail loudly,
            // not silently misreport the schedule mode
            (
                "badbatch.json",
                r#"{"format":"eakm-fitted-model","version":1,"k":1,"d":1,"seed":"0","batch_size":8,"centroids":[0]}"#,
            ),
            (
                "badschedule.json",
                r#"{"format":"eakm-fitted-model","version":1,"k":1,"d":1,"seed":"0","batch_size":8,"batch_growth":2,"batch_schedule":[8,"x"],"centroids":[0]}"#,
            ),
        ];
        for (name, text) in cases {
            let path = tmpfile(name);
            std::fs::write(&path, text).unwrap();
            assert!(FittedModel::load(&path).is_err(), "{name}");
        }
    }
}
