//! Distance-evaluation counters and per-run telemetry.
//!
//! The paper's tables compare `q_t` (wall time), `q_a` (distance
//! calculations in the assignment step) and `q_au` (total distance
//! calculations). [`Counters`] keeps exactly those decompositions.

use std::time::Duration;

/// Counts of point-to-point distance evaluations, by site.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    /// x↔c distances evaluated during assignment steps (paper's `a`).
    pub assignment: u64,
    /// c↔c distances: inter-centroid matrix, s(j), annuli construction.
    pub centroid: u64,
    /// centroid-displacement norms: p(j) each round, ns-history P(j,t).
    pub displacement: u64,
    /// distances spent during initial seeding + first full assignment.
    pub init: u64,
}

impl Counters {
    /// Paper's `au`: all distance evaluations.
    pub fn total(&self) -> u64 {
        self.assignment + self.centroid + self.displacement + self.init
    }

    /// Merge another counter set (used when joining worker shards).
    pub fn merge(&mut self, other: &Counters) {
        self.assignment += other.assignment;
        self.centroid += other.centroid;
        self.displacement += other.displacement;
        self.init += other.init;
    }

    /// Counter delta `self − earlier` (saturating). Feeds the per-round
    /// distance-calculation deltas in [`obs`](crate::obs) round events.
    pub fn since(&self, earlier: &Counters) -> Counters {
        Counters {
            assignment: self.assignment.saturating_sub(earlier.assignment),
            centroid: self.centroid.saturating_sub(earlier.centroid),
            displacement: self.displacement.saturating_sub(earlier.displacement),
            init: self.init.saturating_sub(earlier.init),
        }
    }
}

/// Wall-time decomposition of the round loop by phase, accumulated
/// across rounds. Lets `table6_multicore` attribute parallel speedup to
/// the sample scan vs the coordinator's centroid-side work.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PhaseTimes {
    /// Assignment scans (the initial full assignment + every round),
    /// sharded over samples.
    pub scan: Duration,
    /// Centroid update: delta apply / full recompute + new centroid
    /// means.
    pub update: Duration,
    /// Centroid-side per-round builds: `p(j)` + norms, the `cc` matrix,
    /// annuli, group maxima, and the ns history table.
    pub build: Duration,
}

impl PhaseTimes {
    /// Sum of all phases.
    pub fn total(&self) -> Duration {
        self.scan + self.update + self.build
    }

    /// Accumulate another decomposition (the mini-batch driver folds
    /// each per-batch engine's phases into the fit-wide report).
    pub fn merge(&mut self, other: &PhaseTimes) {
        self.scan += other.scan;
        self.update += other.update;
        self.build += other.build;
    }
}

/// I/O telemetry for out-of-core data sources (`None` when the run read
/// resident memory): cumulative counts from the source's cursors.
/// [`DataSource::io_stats`](crate::data::DataSource::io_stats) returns a
/// snapshot; runners report the delta of two snapshots, so the numbers
/// are per-run even when one source serves many runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IoTelemetry {
    /// Row blocks leased from cursors.
    pub blocks_leased: u64,
    /// Bytes read from the backing file (mmap sources count bytes
    /// leased — actual paging is the kernel's business).
    pub bytes_read: u64,
    /// Resident-window refills (0 for mmap sources).
    pub window_refills: u64,
}

impl IoTelemetry {
    /// Counter delta `self − earlier` (saturating, so a source swap
    /// mid-run degrades to zeros instead of nonsense).
    pub fn since(&self, earlier: &IoTelemetry) -> IoTelemetry {
        IoTelemetry {
            blocks_leased: self.blocks_leased.saturating_sub(earlier.blocks_leased),
            bytes_read: self.bytes_read.saturating_sub(earlier.bytes_read),
            window_refills: self.window_refills.saturating_sub(earlier.window_refills),
        }
    }
}

/// Scan-scheduler telemetry: how the over-decomposed
/// [`ScanPlan`](crate::coordinator::sched::ScanPlan) behaved over the
/// run. Shard *walls* are accumulated across dispatches (one dispatch =
/// one pooled assignment scan), split by phase: `init` covers the
/// initial full assignment, `scan` every subsequent round. The
/// max/mean ratio is the straggler signal — how much longer the
/// slowest shard ran than the average one each round.
///
/// Wall times are measured, not derived, so they vary run to run;
/// everything that feeds back into scheduling (the per-shard cost
/// counters driving LPT order) is deterministic. Telemetry never
/// affects results.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SchedTelemetry {
    /// Shards in the scan plan (a function of `n` alone).
    pub shards: usize,
    /// Pooled scan dispatches (initial assignment + one per round).
    pub dispatches: u64,
    /// Dispatches whose LPT claim order differed from the previous
    /// dispatch's — how often the cost feedback actually re-ranked
    /// shards.
    pub reorders: u64,
    /// Slowest-shard wall time, summed over init dispatches.
    pub init_max: Duration,
    /// Mean shard wall time, summed over init dispatches.
    pub init_mean: Duration,
    /// Slowest-shard wall time, summed over round-scan dispatches.
    pub scan_max: Duration,
    /// Mean shard wall time, summed over round-scan dispatches.
    pub scan_mean: Duration,
}

impl SchedTelemetry {
    /// Straggler ratio for the round scans: accumulated slowest-shard
    /// wall over accumulated mean shard wall (falls back to the init
    /// dispatch when no rounds ran; 1.0 when nothing was measured).
    /// 1.0 = perfectly balanced; `w` = one shard gated every round of
    /// a `w`-wide pool.
    pub fn imbalance(&self) -> f64 {
        let (max, mean) = if self.scan_mean > Duration::ZERO {
            (self.scan_max, self.scan_mean)
        } else {
            (self.init_max, self.init_mean)
        };
        if mean > Duration::ZERO {
            max.as_secs_f64() / mean.as_secs_f64()
        } else {
            1.0
        }
    }

    /// Accumulate another run's scheduler telemetry (the mini-batch
    /// driver folds each per-batch engine's block into the fit-wide
    /// report). Shard counts take the max — batches share a geometry
    /// policy but may differ in `n`.
    pub fn merge(&mut self, other: &SchedTelemetry) {
        self.shards = self.shards.max(other.shards);
        self.dispatches += other.dispatches;
        self.reorders += other.reorders;
        self.init_max += other.init_max;
        self.init_mean += other.init_mean;
        self.scan_max += other.scan_max;
        self.scan_mean += other.scan_mean;
    }
}

/// Batch-schedule telemetry for a mini-batch fit (`None` on exact
/// full-batch runs): the resolved knobs plus the realised schedule.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BatchTelemetry {
    /// Initial batch size after clamping to `[k, n]`.
    pub batch_size: usize,
    /// Growth factor per round (1.0 = fresh redraw each round).
    pub growth: f64,
    /// Rows scanned in each mini-batch round, in order — nested runs
    /// show the doubling staircase, redraw runs a flat line.
    pub schedule: Vec<usize>,
}

/// Telemetry for one completed clustering run.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Algorithm name (paper notation, e.g. "exp-ns").
    pub algorithm: String,
    /// Dataset name.
    pub dataset: String,
    /// Number of clusters.
    pub k: usize,
    /// Training rows the fit scanned (0 when unknown, e.g. a report
    /// reloaded from a model file written before this field existed).
    /// Normalises the counters into the paper-grounded
    /// bounds-effectiveness rates — distance calculations *per point
    /// per round* — that serve exposes as live gauges.
    pub n: usize,
    /// Seed used.
    pub seed: u64,
    /// Rounds until convergence (or cut-off).
    pub iterations: usize,
    /// Whether the run converged (no assignment changed).
    pub converged: bool,
    /// Final mean squared error (objective / n).
    pub mse: f64,
    /// Wall time of the clustering loop (excludes data generation).
    pub wall: Duration,
    /// Worker threads used (resolved, ≥ 1).
    pub threads: usize,
    /// Per-phase wall-time decomposition of the round loop.
    pub phases: PhaseTimes,
    /// Distance-evaluation counters.
    pub counters: Counters,
    /// Wall time per round, if recorded.
    pub round_times: Vec<Duration>,
    /// Mini-batch schedule telemetry (`None` for full-batch runs).
    pub batch: Option<BatchTelemetry>,
    /// Out-of-core I/O telemetry (`None` for resident sources).
    pub io: Option<IoTelemetry>,
    /// Scan-scheduler telemetry (zeroed when no scan was dispatched,
    /// e.g. a report reloaded from an old model file).
    pub sched: SchedTelemetry,
}

impl RunReport {
    /// A counter site normalised to distance calculations **per point
    /// per round** — the paper-grounded bounds-effectiveness rate
    /// (Lloyd's algorithm pays exactly `k` per point per round; the
    /// bounded algorithms' whole contribution is driving this far
    /// below `k`). Returns 0.0 when `n` or `iterations` is unknown.
    pub fn per_point_round(&self, site: u64) -> f64 {
        let denom = self.n as f64 * self.iterations as f64;
        if denom > 0.0 {
            site as f64 / denom
        } else {
            0.0
        }
    }

    /// Render one compact human-readable line.
    pub fn summary(&self) -> String {
        let batch = match &self.batch {
            Some(b) => format!(
                " batch={}→{}×{:.2}",
                b.batch_size,
                b.schedule.last().copied().unwrap_or(b.batch_size),
                b.growth,
            ),
            None => String::new(),
        };
        let io = match &self.io {
            Some(io) => format!(
                " io: blocks={} bytes={} refills={}",
                io.blocks_leased, io.bytes_read, io.window_refills
            ),
            None => String::new(),
        };
        let sched = if self.sched.dispatches > 0 {
            format!(
                " sched: S={} reord={} imb={:.2}",
                self.sched.shards,
                self.sched.reorders,
                self.sched.imbalance()
            )
        } else {
            String::new()
        };
        format!(
            "{:<10} {:<14} k={:<5} iters={:<5} conv={} mse={:.6} wall={:?} q_a={} q_au={} thr={} scan={:?} upd={:?} build={:?}{sched}{batch}{io}",
            self.algorithm,
            self.dataset,
            self.k,
            self.iterations,
            self.converged,
            self.mse,
            self.wall,
            self.counters.assignment,
            self.counters.total(),
            self.threads,
            self.phases.scan,
            self.phases.update,
            self.phases.build,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_sums_all_sites() {
        let c = Counters {
            assignment: 10,
            centroid: 3,
            displacement: 2,
            init: 5,
        };
        assert_eq!(c.total(), 20);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Counters {
            assignment: 1,
            centroid: 2,
            displacement: 3,
            init: 4,
        };
        let b = a;
        a.merge(&b);
        assert_eq!(a.total(), 20);
        assert_eq!(a.assignment, 2);
    }

    #[test]
    fn summary_contains_fields() {
        let r = RunReport {
            algorithm: "exp".into(),
            dataset: "birch".into(),
            k: 100,
            n: 5000,
            seed: 1,
            iterations: 42,
            converged: true,
            mse: 0.5,
            wall: Duration::from_millis(10),
            threads: 4,
            phases: PhaseTimes::default(),
            counters: Counters::default(),
            round_times: vec![],
            batch: None,
            io: None,
            sched: SchedTelemetry::default(),
        };
        let s = r.summary();
        assert!(s.contains("exp") && s.contains("birch") && s.contains("iters=42"));
        assert!(s.contains("thr=4"));
        assert_eq!(r.per_point_round(0), 0.0);
        assert!((r.per_point_round(5000 * 42 * 3) - 3.0).abs() < 1e-12);
        assert!(!s.contains("batch="));
        assert!(!s.contains("io:"));
        assert!(!s.contains("sched:"));
        let r = RunReport {
            batch: Some(BatchTelemetry {
                batch_size: 256,
                growth: 2.0,
                schedule: vec![256, 512, 1024],
            }),
            io: Some(IoTelemetry {
                blocks_leased: 7,
                bytes_read: 4096,
                window_refills: 2,
            }),
            sched: SchedTelemetry {
                shards: 32,
                dispatches: 43,
                reorders: 5,
                init_max: Duration::from_millis(4),
                init_mean: Duration::from_millis(2),
                scan_max: Duration::from_millis(30),
                scan_mean: Duration::from_millis(20),
            },
            ..r
        };
        let s = r.summary();
        assert!(s.contains("batch=256→1024×2.00"));
        assert!(s.contains("io: blocks=7 bytes=4096 refills=2"));
        assert!(s.contains("sched: S=32 reord=5 imb=1.50"));
    }

    #[test]
    fn sched_imbalance_ratio() {
        // nothing measured → balanced by definition
        assert_eq!(SchedTelemetry::default().imbalance(), 1.0);
        // rounds dominate when present
        let t = SchedTelemetry {
            shards: 8,
            dispatches: 3,
            reorders: 1,
            init_max: Duration::from_millis(100),
            init_mean: Duration::from_millis(10),
            scan_max: Duration::from_millis(40),
            scan_mean: Duration::from_millis(20),
        };
        assert!((t.imbalance() - 2.0).abs() < 1e-9);
        // init-only run falls back to the init dispatch
        let t = SchedTelemetry {
            scan_max: Duration::ZERO,
            scan_mean: Duration::ZERO,
            ..t
        };
        assert!((t.imbalance() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn sched_merge_accumulates() {
        let a = SchedTelemetry {
            shards: 8,
            dispatches: 2,
            reorders: 1,
            init_max: Duration::from_millis(1),
            init_mean: Duration::from_millis(1),
            scan_max: Duration::from_millis(6),
            scan_mean: Duration::from_millis(3),
        };
        let mut b = SchedTelemetry { shards: 4, ..a };
        b.merge(&a);
        assert_eq!(b.shards, 8); // max, not sum
        assert_eq!(b.dispatches, 4);
        assert_eq!(b.reorders, 2);
        assert_eq!(b.scan_max, Duration::from_millis(12));
        assert_eq!(b.scan_mean, Duration::from_millis(6));
    }

    #[test]
    fn io_delta_saturates() {
        let a = IoTelemetry {
            blocks_leased: 10,
            bytes_read: 100,
            window_refills: 1,
        };
        let b = IoTelemetry {
            blocks_leased: 25,
            bytes_read: 900,
            window_refills: 4,
        };
        assert_eq!(
            b.since(&a),
            IoTelemetry {
                blocks_leased: 15,
                bytes_read: 800,
                window_refills: 3
            }
        );
        assert_eq!(a.since(&b), IoTelemetry::default());
    }

    #[test]
    fn phase_times_total() {
        let p = PhaseTimes {
            scan: Duration::from_millis(5),
            update: Duration::from_millis(2),
            build: Duration::from_millis(3),
        };
        assert_eq!(p.total(), Duration::from_millis(10));
        let mut q = p;
        q.merge(&p);
        assert_eq!(q.total(), Duration::from_millis(20));
        assert_eq!(q.scan, Duration::from_millis(10));
    }
}
