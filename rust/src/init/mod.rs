//! Centroid initialisation: uniform sampling (the paper's seeding) and
//! k-means++ (D² seeding) as an extension.

pub mod kmeanspp;
pub mod random;

use crate::data::DataSource;
use crate::metrics::Counters;
use crate::rng::Rng;

/// Which seeding strategy to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitMethod {
    /// k distinct samples uniformly at random — what the paper's
    /// "10 distinct centroid initialisations (seeds)" refers to.
    Random,
    /// k-means++ D² seeding (extension; costs k passes of distances).
    KmeansPlusPlus,
}

impl InitMethod {
    /// Parse from CLI text.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "random" | "uniform" => Some(InitMethod::Random),
            "kmeans++" | "kmeanspp" | "pp" => Some(InitMethod::KmeansPlusPlus),
            _ => None,
        }
    }

    /// Produce `k` initial centroids (row-major `k×d`).
    pub fn centroids(
        &self,
        data: &dyn DataSource,
        k: usize,
        rng: &mut Rng,
        counters: &mut Counters,
    ) -> Vec<f64> {
        match self {
            InitMethod::Random => random::init(data, k, rng),
            InitMethod::KmeansPlusPlus => kmeanspp::init(data, k, rng, counters),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(InitMethod::parse("random"), Some(InitMethod::Random));
        assert_eq!(InitMethod::parse("pp"), Some(InitMethod::KmeansPlusPlus));
        assert_eq!(InitMethod::parse("x"), None);
    }
}
