//! k-means++ (Arthur & Vassilvitskii 2007) D² seeding.
//!
//! Not used by the paper's experiments (which seed uniformly) but
//! provided as a library feature; its distance evaluations are counted
//! in [`Counters::init`] so experiment accounting stays exact.

use crate::data::DataSource;
use crate::linalg::sqdist;
use crate::metrics::Counters;
use crate::rng::Rng;

/// D² seeding: first centroid uniform, each next sampled ∝ squared
/// distance to the nearest chosen centroid.
pub fn init(data: &dyn DataSource, k: usize, rng: &mut Rng, counters: &mut Counters) -> Vec<f64> {
    assert!(k > 0 && k <= data.n(), "k={k} out of range for n={}", data.n());
    let (n, d) = (data.n(), data.d());
    let mut centroids = Vec::with_capacity(k * d);
    // one cursor serves both the chosen-row gathers and the distance
    // passes; the chosen row is copied into `centroids` first, so the
    // pass below compares leases against owned memory (a lease expires
    // at the next lease from the same cursor)
    let mut cur = data.open(0, n);
    let first = rng.below(n);
    centroids.extend_from_slice(cur.row(first));

    // nearest-chosen-centroid squared distance per sample
    let mut d2 = vec![0.0; n];
    for (i, slot) in d2.iter_mut().enumerate() {
        *slot = sqdist(cur.row(i), &centroids[..d]);
    }
    counters.init += n as u64;

    for _ in 1..k {
        let next = match rng.weighted(&d2) {
            Some(i) => i,
            // All remaining mass is zero (duplicate-heavy data): fall back
            // to uniform among samples, keeping determinism.
            None => rng.below(n),
        };
        let start = centroids.len();
        centroids.extend_from_slice(cur.row(next));
        let row = &centroids[start..start + d];
        for (i, slot) in d2.iter_mut().enumerate() {
            let dist = sqdist(cur.row(i), row);
            if dist < *slot {
                *slot = dist;
            }
        }
        counters.init += n as u64;
    }
    centroids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::data::Dataset;

    #[test]
    fn produces_k_by_d() {
        let ds = blobs(300, 5, 4, 0.05, 8);
        let mut c = Counters::default();
        let out = init(&ds, 7, &mut Rng::new(1), &mut c);
        assert_eq!(out.len(), 7 * 5);
        assert_eq!(c.init, 7 * 300);
    }

    #[test]
    fn spreads_over_separated_blobs() {
        // 4 well-separated blobs, k=4 → ++ should hit all 4 almost surely
        let mut data = Vec::new();
        let offsets = [(0.0, 0.0), (100.0, 0.0), (0.0, 100.0), (100.0, 100.0)];
        let mut rng = Rng::new(2);
        for &(ox, oy) in &offsets {
            for _ in 0..50 {
                data.push(ox + rng.normal() * 0.1);
                data.push(oy + rng.normal() * 0.1);
            }
        }
        let ds = Dataset::new("four", data, 200, 2).unwrap();
        let mut c = Counters::default();
        let cents = init(&ds, 4, &mut Rng::new(3), &mut c);
        // each blob owns exactly one centroid
        let mut hits = [0; 4];
        for j in 0..4 {
            let cx = cents[j * 2];
            let cy = cents[j * 2 + 1];
            for (b, &(ox, oy)) in offsets.iter().enumerate() {
                if (cx - ox).abs() < 10.0 && (cy - oy).abs() < 10.0 {
                    hits[b] += 1;
                }
            }
        }
        assert_eq!(hits, [1, 1, 1, 1]);
    }

    #[test]
    fn handles_duplicate_points() {
        let ds = Dataset::new("dup", vec![1.0; 20], 10, 2).unwrap();
        let mut c = Counters::default();
        let out = init(&ds, 3, &mut Rng::new(5), &mut c);
        assert_eq!(out.len(), 6);
        assert!(out.iter().all(|&v| v == 1.0));
    }
}
