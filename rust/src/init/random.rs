//! Uniform random seeding: k distinct samples become the centroids.

use crate::data::DataSource;
use crate::rng::Rng;

/// Pick `k` distinct samples as initial centroids.
///
/// Panics if `k == 0` or `k > n` (callers validate through `RunConfig`).
pub fn init(data: &dyn DataSource, k: usize, rng: &mut Rng) -> Vec<f64> {
    assert!(k > 0 && k <= data.n(), "k={k} out of range for n={}", data.n());
    let d = data.d();
    let idxs = rng.distinct(data.n(), k);
    // one cursor for the whole gather: draws are random-access leases
    let mut cur = data.open(0, data.n());
    let mut out = Vec::with_capacity(k * d);
    for &i in &idxs {
        out.extend_from_slice(cur.row(i));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;

    #[test]
    fn picks_k_distinct_rows() {
        let ds = blobs(100, 4, 3, 0.1, 2);
        let mut rng = Rng::new(3);
        let c = init(&ds, 10, &mut rng);
        assert_eq!(c.len(), 10 * 4);
        // every centroid equals some data row
        for j in 0..10 {
            let cj = &c[j * 4..(j + 1) * 4];
            assert!((0..ds.n()).any(|i| ds.row(i) == cj));
        }
        // distinct rows (data is continuous, collisions impossible)
        for a in 0..10 {
            for b in (a + 1)..10 {
                assert_ne!(&c[a * 4..(a + 1) * 4], &c[b * 4..(b + 1) * 4]);
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let ds = blobs(50, 2, 2, 0.1, 2);
        let a = init(&ds, 5, &mut Rng::new(9));
        let b = init(&ds, 5, &mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn rejects_k_gt_n() {
        let ds = blobs(10, 2, 2, 0.1, 2);
        init(&ds, 11, &mut Rng::new(1));
    }
}
