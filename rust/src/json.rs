//! A minimal JSON writer for reports and bench outputs (no external
//! dependencies are available offline, and we only ever *emit* JSON).

use std::fmt::Write as _;

/// A JSON value under construction.
#[derive(Clone, Debug)]
pub enum Json {
    /// null
    Null,
    /// true/false
    Bool(bool),
    /// Any finite number (non-finite serialises as null).
    Num(f64),
    /// String (escaped on write).
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object with insertion-ordered keys.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Start an empty object.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Add a field to an object (panics on non-objects — builder misuse).
    pub fn field(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), value.into())),
            _ => panic!("field() on non-object"),
        }
        self
    }

    /// Serialise to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // integers print without a trailing .0
                    if *x == x.trunc() && x.abs() < 9e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (key, value)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(key.clone()).write(out);
                    out.push(':');
                    value.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

impl From<&crate::metrics::RunReport> for Json {
    fn from(r: &crate::metrics::RunReport) -> Json {
        Json::obj()
            .field("algorithm", r.algorithm.as_str())
            .field("dataset", r.dataset.as_str())
            .field("k", r.k)
            .field("seed", r.seed)
            .field("iterations", r.iterations)
            .field("converged", r.converged)
            .field("mse", r.mse)
            .field("wall_secs", r.wall.as_secs_f64())
            .field("threads", r.threads)
            .field("scan_secs", r.phases.scan.as_secs_f64())
            .field("update_secs", r.phases.update.as_secs_f64())
            .field("build_secs", r.phases.build.as_secs_f64())
            .field("q_a", r.counters.assignment)
            .field("q_centroid", r.counters.centroid)
            .field("q_displacement", r.counters.displacement)
            .field("q_init", r.counters.init)
            .field("q_au", r.counters.total())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_values() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::Bool(true).to_string(), "true");
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn string_escaping() {
        assert_eq!(
            Json::Str("a\"b\\c\nd".into()).to_string(),
            r#""a\"b\\c\nd""#
        );
        assert_eq!(Json::Str("\u{1}".into()).to_string(), "\"\\u0001\"");
    }

    #[test]
    fn nested_object() {
        let j = Json::obj()
            .field("name", "exp")
            .field("k", 100usize)
            .field("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)]));
        assert_eq!(j.to_string(), r#"{"name":"exp","k":100,"xs":[1,2.5]}"#);
    }

    #[test]
    fn report_serialises() {
        let r = crate::metrics::RunReport {
            algorithm: "exp".into(),
            dataset: "birch".into(),
            k: 10,
            seed: 1,
            iterations: 5,
            converged: true,
            mse: 0.25,
            wall: std::time::Duration::from_millis(1500),
            threads: 2,
            phases: Default::default(),
            counters: Default::default(),
            round_times: vec![],
        };
        let s = Json::from(&r).to_string();
        assert!(s.contains(r#""algorithm":"exp""#));
        assert!(s.contains(r#""wall_secs":1.5"#));
        assert!(s.contains(r#""threads":2"#));
        assert!(s.contains(r#""scan_secs":0"#));
    }
}
