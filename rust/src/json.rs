//! A minimal JSON writer *and parser* (no external dependencies are
//! available offline). Emitting covers reports and bench outputs;
//! parsing exists so [`FittedModel`](crate::model::FittedModel) files
//! survive process restarts.
//!
//! Numbers round-trip bit-identically for all finite `f64`: the writer
//! uses Rust's shortest-roundtrip float formatting and the parser feeds
//! the numeric token back through `str::parse::<f64>()`.

use std::fmt::Write as _;

use crate::error::{EakmError, Result};

/// A JSON value under construction.
#[derive(Clone, Debug)]
pub enum Json {
    /// null
    Null,
    /// true/false
    Bool(bool),
    /// Any finite number (non-finite serialises as null).
    Num(f64),
    /// String (escaped on write).
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object with insertion-ordered keys.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Start an empty object.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Add a field to an object (panics on non-objects — builder misuse).
    pub fn field(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), value.into())),
            _ => panic!("field() on non-object"),
        }
        self
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // integers print without a trailing .0 (except -0.0,
                    // which must keep its sign to round-trip bit-exactly)
                    let negative_zero = *x == 0.0 && x.is_sign_negative();
                    if *x == x.trunc() && x.abs() < 9e15 && !negative_zero {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (key, value)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(key.clone()).write(out);
                    out.push(':');
                    value.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (must consume the whole input) under the
    /// trusted-file limits ([`ParseLimits::document`]).
    pub fn parse(text: &str) -> Result<Json> {
        Self::parse_with_limits(text, &ParseLimits::document())
    }

    /// Parse a JSON document under explicit resource limits. This is
    /// the entry point for **untrusted** input (the serve front-end
    /// parses attacker-controlled bytes): oversized documents and
    /// over-deep nesting are rejected with [`EakmError::Limit`] before
    /// they can cost unbounded stack or allocation. Memory use is
    /// bounded by the byte cap — every parsed value consumes at least
    /// one input byte, so allocation is `O(max_bytes)`.
    pub fn parse_with_limits(text: &str, limits: &ParseLimits) -> Result<Json> {
        if text.len() > limits.max_bytes {
            return Err(EakmError::Limit(format!(
                "json document of {} bytes exceeds the {}-byte limit",
                text.len(),
                limits.max_bytes
            )));
        }
        let mut p = Parser {
            s: text.as_bytes(),
            pos: 0,
            depth: 0,
            max_depth: limits.max_depth,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.s.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Field lookup on an object (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The number, if this is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number as a non-negative integer, if it is exactly one.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && *x == x.trunc() && *x < 9e15 => Some(*x as usize),
            _ => None,
        }
    }

    /// The string, if this is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// The boolean, if this is one.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The array items, if this is one.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items.as_slice()),
            _ => None,
        }
    }
}

/// Compact serialisation (`.to_string()` comes via `ToString`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

/// Resource caps for [`Json::parse_with_limits`].
///
/// Two profiles cover the crate's inputs: [`document`](ParseLimits::document)
/// for trusted local files (model JSON, bench artifacts) and
/// [`network`](ParseLimits::network) for bytes read off a socket, where
/// both caps are deliberately tight.
#[derive(Clone, Copy, Debug)]
pub struct ParseLimits {
    /// Reject documents longer than this many bytes before parsing.
    pub max_bytes: usize,
    /// Reject container nesting deeper than this many levels (caps the
    /// parse recursion's stack).
    pub max_depth: usize,
}

impl ParseLimits {
    /// Trusted-file profile: no byte cap, 128 nesting levels (crafted
    /// files must still error instead of overflowing the stack).
    pub fn document() -> ParseLimits {
        ParseLimits {
            max_bytes: usize::MAX,
            max_depth: 128,
        }
    }

    /// Untrusted-network profile: 4 MiB, 64 nesting levels. The serve
    /// protocol is flat (depth 3), so 64 is already generous.
    pub fn network() -> ParseLimits {
        ParseLimits {
            max_bytes: 4 << 20,
            max_depth: 64,
        }
    }
}

/// Recursive-descent parser over the document bytes. Inputs are `&str`,
/// so multi-byte UTF-8 runs are copied through verbatim (they can only
/// be delimited by ASCII structural bytes, which sit on char
/// boundaries).
struct Parser<'a> {
    s: &'a [u8],
    pos: usize,
    /// Current container-nesting depth (capped at `max_depth`).
    depth: usize,
    /// Cap from the active [`ParseLimits`].
    max_depth: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> EakmError {
        EakmError::Data(format!("json (byte {}): {msg}", self.pos))
    }

    fn skip_ws(&mut self) {
        while matches!(self.s.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(c @ (b'{' | b'[')) => {
                if self.depth >= self.max_depth {
                    return Err(EakmError::Limit(format!(
                        "json (byte {}): nesting deeper than {} levels",
                        self.pos, self.max_depth
                    )));
                }
                self.depth += 1;
                let v = if c == b'{' { self.object() } else { self.array() };
                self.depth -= 1;
                v
            }
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.s[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected {word:?}")))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let token = std::str::from_utf8(&self.s[start..self.pos]).expect("ascii token");
        let x: f64 = token
            .parse()
            .map_err(|_| self.err(&format!("bad number {token:?}")))?;
        if !x.is_finite() {
            return Err(self.err(&format!("number out of range {token:?}")));
        }
        Ok(Json::Num(x))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let run_start = self.pos;
            // copy the longest escape-free run in one go
            while let Some(c) = self.peek() {
                if c == b'"' || c == b'\\' || c < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            out.push_str(
                std::str::from_utf8(&self.s[run_start..self.pos])
                    .map_err(|_| self.err("invalid utf-8 in string"))?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    out.push(self.escape()?);
                }
                Some(_) => return Err(self.err("control character in string")),
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn escape(&mut self) -> Result<char> {
        let c = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
        self.pos += 1;
        Ok(match c {
            b'"' => '"',
            b'\\' => '\\',
            b'/' => '/',
            b'n' => '\n',
            b'r' => '\r',
            b't' => '\t',
            b'b' => '\u{8}',
            b'f' => '\u{c}',
            b'u' => {
                let hi = self.hex4()?;
                let cp = if (0xD800..0xDC00).contains(&hi) {
                    // surrogate pair: a second \uXXXX must follow
                    self.expect(b'\\')?;
                    self.expect(b'u')?;
                    let lo = self.hex4()?;
                    if !(0xDC00..0xE000).contains(&lo) {
                        return Err(self.err("unpaired surrogate"));
                    }
                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                } else {
                    hi
                };
                char::from_u32(cp).ok_or_else(|| self.err("invalid \\u escape"))?
            }
            _ => return Err(self.err(&format!("bad escape \\{:?}", c as char))),
        })
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.s.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let token = std::str::from_utf8(&self.s[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(token, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

impl From<&crate::metrics::RunReport> for Json {
    fn from(r: &crate::metrics::RunReport) -> Json {
        let json = Json::obj()
            .field("algorithm", r.algorithm.as_str())
            .field("dataset", r.dataset.as_str())
            .field("k", r.k)
            .field("n", r.n)
            .field("seed", r.seed)
            .field("iterations", r.iterations)
            .field("converged", r.converged)
            .field("mse", r.mse)
            .field("wall_secs", r.wall.as_secs_f64())
            .field("threads", r.threads)
            .field("scan_secs", r.phases.scan.as_secs_f64())
            .field("update_secs", r.phases.update.as_secs_f64())
            .field("build_secs", r.phases.build.as_secs_f64())
            .field("q_a", r.counters.assignment)
            .field("q_centroid", r.counters.centroid)
            .field("q_displacement", r.counters.displacement)
            .field("q_init", r.counters.init)
            .field("q_au", r.counters.total())
            .field("sched_shards", r.sched.shards)
            .field("sched_dispatches", r.sched.dispatches)
            .field("sched_reorders", r.sched.reorders)
            .field("sched_init_max_secs", r.sched.init_max.as_secs_f64())
            .field("sched_init_mean_secs", r.sched.init_mean.as_secs_f64())
            .field("sched_scan_max_secs", r.sched.scan_max.as_secs_f64())
            .field("sched_scan_mean_secs", r.sched.scan_mean.as_secs_f64())
            .field("sched_imbalance", r.sched.imbalance());
        let json = match &r.batch {
            Some(b) => json
                .field("batch_size", b.batch_size)
                .field("batch_growth", b.growth)
                .field(
                    "batch_schedule",
                    Json::Arr(b.schedule.iter().map(|&s| Json::from(s)).collect()),
                ),
            None => json,
        };
        match &r.io {
            Some(io) => json
                .field("io_blocks_leased", io.blocks_leased)
                .field("io_bytes_read", io.bytes_read)
                .field("io_window_refills", io.window_refills),
            None => json,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_values() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::Bool(true).to_string(), "true");
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn string_escaping() {
        assert_eq!(
            Json::Str("a\"b\\c\nd".into()).to_string(),
            r#""a\"b\\c\nd""#
        );
        assert_eq!(Json::Str("\u{1}".into()).to_string(), "\"\\u0001\"");
    }

    #[test]
    fn nested_object() {
        let j = Json::obj()
            .field("name", "exp")
            .field("k", 100usize)
            .field("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)]));
        assert_eq!(j.to_string(), r#"{"name":"exp","k":100,"xs":[1,2.5]}"#);
    }

    #[test]
    fn parse_roundtrips_writer_output() {
        let j = Json::obj()
            .field("name", "exp \"ns\"\n")
            .field("k", 100usize)
            .field("ok", true)
            .field("none", Json::Null)
            .field(
                "xs",
                Json::Arr(vec![Json::Num(1.0), Json::Num(-2.5), Json::Num(3e-17)]),
            );
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.to_string(), text);
        assert_eq!(back.get("k").unwrap().as_usize(), Some(100));
        assert_eq!(back.get("name").unwrap().as_str(), Some("exp \"ns\"\n"));
        assert_eq!(back.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(back.get("xs").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn floats_roundtrip_bit_identically() {
        for x in [
            0.1,
            -0.0,
            1.0 / 3.0,
            f64::MIN_POSITIVE,
            -9.87654321e-200,
            1e300,
            123456789.125,
        ] {
            let text = Json::Num(x).to_string();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} via {text:?}");
        }
    }

    #[test]
    fn parse_handles_whitespace_and_escapes() {
        let j = Json::parse(" { \"a\" : [ 1 , null , \"x\\u0041\\n\" ] } ").unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert!(matches!(arr[1], Json::Null));
        assert_eq!(arr[2].as_str(), Some("xA\n"));
        // astral-plane escape (surrogate pair)
        let j = Json::parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(j.as_str(), Some("😀"));
    }

    #[test]
    fn parse_caps_nesting_depth() {
        // must Err, not overflow the stack
        let deep = "[".repeat(100_000);
        assert!(Json::parse(&deep).is_err());
        // well under the cap still parses
        let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn network_limits_reject_hostile_inputs_with_typed_errors() {
        use crate::error::EakmError;
        let net = ParseLimits::network();
        // 65 levels breaches the 64-level network cap — typed Limit, no
        // stack overflow
        let deep = format!("{}1{}", "[".repeat(65), "]".repeat(65));
        assert!(matches!(
            Json::parse_with_limits(&deep, &net),
            Err(EakmError::Limit(_))
        ));
        // 63 levels is fine (the cap counts containers entered)
        let ok = format!("{}1{}", "[".repeat(63), "]".repeat(63));
        assert!(Json::parse_with_limits(&ok, &net).is_ok());
        // objects hit the same cap as arrays
        let deep_obj = format!("{}1{}", "{\"a\":".repeat(70), "}".repeat(70));
        assert!(matches!(
            Json::parse_with_limits(&deep_obj, &net),
            Err(EakmError::Limit(_))
        ));
        // oversized payloads are rejected before any parsing/allocation
        let tight = ParseLimits {
            max_bytes: 64,
            max_depth: 64,
        };
        let big = format!("[{}]", "1,".repeat(100));
        assert!(matches!(
            Json::parse_with_limits(&big, &tight),
            Err(EakmError::Limit(_))
        ));
        assert!(Json::parse_with_limits("[1,2,3]", &tight).is_ok());
        // malformed bytes under the caps still fail as plain Data errors
        assert!(matches!(
            Json::parse_with_limits("{\"a\":", &net),
            Err(EakmError::Data(_))
        ));
        // the trusted-document profile keeps its historical 128 levels
        let mid = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&mid).is_ok());
        assert!(matches!(
            Json::parse_with_limits(&mid, &net),
            Err(EakmError::Limit(_))
        ));
    }

    #[test]
    fn parse_rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\":}",
            "tru",
            "\"unterminated",
            "1 2",
            "{\"a\":1,}",
            "\"\\u12\"",
            "\"\\ud800x\"",
            "nan",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn report_serialises() {
        let r = crate::metrics::RunReport {
            algorithm: "exp".into(),
            dataset: "birch".into(),
            k: 10,
            n: 500,
            seed: 1,
            iterations: 5,
            converged: true,
            mse: 0.25,
            wall: std::time::Duration::from_millis(1500),
            threads: 2,
            phases: Default::default(),
            counters: Default::default(),
            round_times: vec![],
            batch: None,
            io: None,
            sched: Default::default(),
        };
        let s = Json::from(&r).to_string();
        assert!(s.contains(r#""algorithm":"exp""#));
        assert!(s.contains(r#""wall_secs":1.5"#));
        assert!(s.contains(r#""threads":2"#));
        assert!(s.contains(r#""scan_secs":0"#));
        // sched telemetry is always present (imbalance defaults to 1)
        assert!(s.contains(r#""sched_shards":0"#));
        assert!(s.contains(r#""sched_imbalance":1"#));
        assert!(!s.contains("batch_size"));
        assert!(!s.contains("io_bytes_read"));
        let r = crate::metrics::RunReport {
            batch: Some(crate::metrics::BatchTelemetry {
                batch_size: 128,
                growth: 2.0,
                schedule: vec![128, 256],
            }),
            io: Some(crate::metrics::IoTelemetry {
                blocks_leased: 3,
                bytes_read: 8192,
                window_refills: 1,
            }),
            sched: crate::metrics::SchedTelemetry {
                shards: 16,
                dispatches: 6,
                reorders: 2,
                ..Default::default()
            },
            ..r
        };
        let s = Json::from(&r).to_string();
        assert!(s.contains(r#""batch_size":128"#));
        assert!(s.contains(r#""batch_schedule":[128,256]"#));
        assert!(s.contains(r#""io_blocks_leased":3"#));
        assert!(s.contains(r#""io_bytes_read":8192"#));
        assert!(s.contains(r#""io_window_refills":1"#));
        assert!(s.contains(r#""sched_shards":16"#));
        assert!(s.contains(r#""sched_dispatches":6"#));
        assert!(s.contains(r#""sched_reorders":2"#));
    }
}
