//! `bench_check` — CI gate for the `BENCH_*.json` bench artifacts.
//!
//! Schema mode (the original gate):
//!
//! ```text
//! bench_check <file.json> <bench-name> <table:min_rows> [<table:min_rows>...]
//! ```
//!
//! Exits 0 when the file parses, identifies itself as `<bench-name>`,
//! and contains every listed table with headers, rectangular rows, and
//! at least `min_rows` rows (see [`eakm::bench_support::check`]).
//!
//! Diff mode (cross-commit wall-time regression report):
//!
//! ```text
//! bench_check --diff <old.json> <new.json> [--threshold R] [--min-wall S]
//! ```
//!
//! Matches rows between the two artifacts by their non-timing cells and
//! prints every wall-time delta. Exits 1 when any row regressed by more
//! than `R` (a fraction: 0.5 = +50%, default 0.5) with both sides at
//! least `S` seconds (default 0.05 — micro rows are noise, not signal).
//!
//! Anything else prints the failure and exits 1, failing the
//! `bench-smoke` job.

use eakm::bench_support::{check_bench_json, diff_bench_json, TableSpec};

fn run_schema(args: &[String]) -> Result<String, String> {
    let (path, bench_name) = (&args[0], &args[1]);
    let tables: Vec<TableSpec> = args[2..]
        .iter()
        .map(|a| TableSpec::parse(a).map_err(|e| e.to_string()))
        .collect::<Result<_, _>>()?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    check_bench_json(&text, bench_name, &tables)
        .map(|summary| format!("{path}: {summary}"))
        .map_err(|e| format!("{path}: {e}"))
}

fn run_diff(args: &[String]) -> Result<String, String> {
    let mut paths = Vec::new();
    let mut threshold = 0.5f64;
    let mut min_wall = 0.05f64;
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--threshold" | "--min-wall" => {
                let v = it
                    .next()
                    .ok_or_else(|| format!("{arg} needs a value"))?
                    .parse::<f64>()
                    .map_err(|_| format!("bad value for {arg}"))?;
                if arg == "--threshold" {
                    threshold = v;
                } else {
                    min_wall = v;
                }
            }
            p => paths.push(p.to_string()),
        }
    }
    let [old_path, new_path] = paths.as_slice() else {
        return Err(
            "usage: bench_check --diff <old.json> <new.json> [--threshold R] [--min-wall S]"
                .into(),
        );
    };
    let old = std::fs::read_to_string(old_path).map_err(|e| format!("read {old_path}: {e}"))?;
    let new = std::fs::read_to_string(new_path).map_err(|e| format!("read {new_path}: {e}"))?;
    let (lines, regressions) =
        diff_bench_json(&old, &new, threshold, min_wall).map_err(|e| e.to_string())?;
    let mut out = String::new();
    for line in &lines {
        out.push_str(line);
        out.push('\n');
    }
    if regressions.is_empty() {
        out.push_str(&format!(
            "diff ok: {} rows compared, no regression beyond +{:.0}% (min wall {min_wall}s)",
            lines.len(),
            threshold * 100.0
        ));
        Ok(out)
    } else {
        for r in &regressions {
            out.push_str(&format!(
                "REGRESSION {}: {:.4}s → {:.4}s (limit +{:.0}%)\n",
                r.what,
                r.old,
                r.new,
                threshold * 100.0
            ));
        }
        Err(out)
    }
}

fn run(args: &[String]) -> Result<String, String> {
    match args.first().map(String::as_str) {
        Some("--diff") => run_diff(&args[1..]),
        _ if args.len() >= 3 => run_schema(args),
        _ => Err(
            "usage: bench_check <file.json> <bench-name> <table:min_rows>...\n\
             \u{20}      bench_check --diff <old.json> <new.json> [--threshold R] [--min-wall S]"
                .to_string(),
        ),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(summary) => println!("{summary}"),
        Err(e) => {
            eprintln!("bench_check: {e}");
            std::process::exit(1);
        }
    }
}
