//! `bench_check` — CI gate for the `BENCH_*.json` bench artifacts.
//!
//! ```text
//! bench_check <file.json> <bench-name> <table:min_rows> [<table:min_rows>...]
//! ```
//!
//! Exits 0 when the file parses, identifies itself as `<bench-name>`,
//! and contains every listed table with headers, rectangular rows, and
//! at least `min_rows` rows (see [`eakm::bench_support::check`]).
//! Anything else prints the failure and exits 1, failing the
//! `bench-smoke` job.

use eakm::bench_support::{check_bench_json, TableSpec};

fn run(args: &[String]) -> Result<String, String> {
    if args.len() < 3 {
        return Err("usage: bench_check <file.json> <bench-name> <table:min_rows>...".to_string());
    }
    let (path, bench_name) = (&args[0], &args[1]);
    let tables: Vec<TableSpec> = args[2..]
        .iter()
        .map(|a| TableSpec::parse(a).map_err(|e| e.to_string()))
        .collect::<Result<_, _>>()?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    check_bench_json(&text, bench_name, &tables)
        .map(|summary| format!("{path}: {summary}"))
        .map_err(|e| format!("{path}: {e}"))
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(summary) => println!("{summary}"),
        Err(e) => {
            eprintln!("bench_check: {e}");
            std::process::exit(1);
        }
    }
}
