//! `eakm` binary — thin shell over [`eakm::cli`].

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match eakm::cli::main(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("eakm: {e}");
            std::process::exit(2);
        }
    }
}
