//! ns-bound algorithm variants (paper §3.2–3.4).
//!
//! Instead of drifting bounds by per-round displacement sums (sn), these
//! remember *when* each bound was last tight (`T`) and the exact distance
//! then (`base`), and correct by the norm-of-sum
//! `P(j,T) = ‖c_now(j) − c_T(j)‖` from the coordinator's
//! [`HistoryStore`](crate::coordinator::history::HistoryStore). Strictly
//! tighter by the triangle inequality (SM-B.5); costs `O(k·t·d)` memory,
//! bounded by the paper's periodic sn-style reset.

pub mod elk_ns;
pub mod exp_ns;
pub mod selk_ns;
pub mod syin_ns;

pub use elk_ns::ElkNs;
pub use exp_ns::ExpNs;
pub use selk_ns::SelkNs;
pub use syin_ns::SyinNs;
