//! `exp-ns` — the Exponion algorithm with ns-bounds (paper §3.4): the
//! paper's two contributions composed, and its best performer on
//! low-dimensional data.
//!
//! The single lower bound uses the MNS update from SM-C.2: the stored
//! base is the exact second-nearest distance at round `T_l(i)` and the
//! effective bound subtracts `max_{j≠a(i)} P(j, T_l(i))` (O(1) via the
//! epoch's max/argmax/second-max tables).

use crate::algorithms::common::{
    batch_scan, dist_ic, top2_sqrt, AssignStep, Moved, Requirements, SharedRound,
};
use crate::data::source::BlockCursor;
use crate::linalg::Top2;
use crate::metrics::Counters;

/// exp-ns per-sample state.
pub struct ExpNs {
    lo: usize,
    /// Exact distance to assigned centroid at epoch round `tu`.
    u: Vec<f64>,
    tu: Vec<u32>,
    /// Exact second-nearest distance at epoch round `tl`.
    l: Vec<f64>,
    tl: Vec<u32>,
}

impl ExpNs {
    /// Create for a shard `[lo, lo+len)`.
    pub fn new(lo: usize, len: usize) -> Self {
        ExpNs {
            lo,
            u: vec![0.0; len],
            tu: vec![0; len],
            l: vec![0.0; len],
            tl: vec![0; len],
        }
    }
}

impl AssignStep for ExpNs {
    fn name(&self) -> &'static str {
        "exp-ns"
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn requirements(&self) -> Requirements {
        Requirements {
            cc: true,
            annuli: true,
            history: true,
            ..Requirements::default()
        }
    }

    fn init(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
    ) {
        let lo = self.lo;
        let (u, l) = (&mut self.u, &mut self.l);
        batch_scan(sh, rows, lo, lo + a.len(), ctr, |li, row| {
            let t2 = top2_sqrt(row);
            a[li] = t2.idx1 as u32;
            u[li] = t2.val1;
            l[li] = t2.val2;
        });
    }

    fn round(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
        moved: &mut Vec<Moved>,
    ) {
        let lo = self.lo;
        let annuli = sh.annuli.expect("exp-ns requires annuli");
        let h = sh.history.expect("ns variant requires history");
        let ep = &h.epoch;
        let t_now = (ep.len - 1) as u32;
        for (li, a_li) in a.iter_mut().enumerate() {
            let ai = *a_li as usize;
            let gi = lo + li;
            if let Some(fold) = &h.fold {
                self.u[li] += fold.p(ai, self.tu[li] as usize);
                self.tu[li] = 0;
                self.l[li] -= fold.maxp_excl(ai, self.tl[li] as usize);
                self.tl[li] = 0;
            }
            let mut eu = self.u[li] + ep.p(ai, self.tu[li] as usize);
            let el = self.l[li] - ep.maxp_excl(ai, self.tl[li] as usize);
            let m = el.max(sh.s(ai) * 0.5);
            if m >= eu {
                continue;
            }
            if self.tu[li] != t_now {
                ctr.assignment += 1;
                eu = crate::linalg::sqdist(rows.row(gi), sh.centroid(ai)).sqrt();
                self.u[li] = eu;
                self.tu[li] = t_now;
                if m >= eu {
                    continue;
                }
            }
            // exponion scan with tight u
            let r = 2.0 * eu + sh.s(ai);
            let mut t2 = Top2::new();
            t2.push(ai, eu);
            for &j in annuli.candidates(ai, r) {
                t2.push(j as usize, dist_ic(sh, rows, gi, j as usize, ctr));
            }
            self.u[li] = t2.val1;
            self.tu[li] = t_now;
            self.l[li] = t2.val2;
            self.tl[li] = t_now;
            if t2.idx1 != ai {
                moved.push(Moved {
                    i: gi as u32,
                    from: ai as u32,
                    to: t2.idx1 as u32,
                });
                *a_li = t2.idx1 as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::*;

    #[test]
    fn matches_sta_on_blobs() {
        assert_exact_vs_sta(|lo, len, _k, _g| Box::new(ExpNs::new(lo, len)), 400, 4, 10, 79);
    }

    #[test]
    fn matches_sta_low_dim_many_clusters() {
        assert_exact_vs_sta(|lo, len, _k, _g| Box::new(ExpNs::new(lo, len)), 800, 2, 32, 83);
    }

    #[test]
    fn matches_sta_with_history_resets() {
        assert_exact_vs_sta_with_reset(
            |lo, len, _k, _g| Box::new(ExpNs::new(lo, len)),
            300,
            3,
            8,
            89,
            3,
        );
    }

    #[test]
    fn bounds_remain_valid_every_round() {
        assert_bounds_valid(
            |lo, len, _k, _g| Box::new(ExpNs::new(lo, len)),
            |alg, chk| {
                let s = alg.as_any().downcast_ref::<ExpNs>().unwrap();
                let ep = chk.epoch().expect("history");
                for li in 0..chk.len() {
                    let ai = chk.assignment(li) as usize;
                    chk.upper(li, s.u[li] + ep.p(ai, s.tu[li] as usize));
                    chk.lower_all(li, s.l[li] - ep.maxp_excl(ai, s.tl[li] as usize));
                }
            },
        );
    }
}
