//! `selk-ns` — Simplified Elkan with ns-bounds (paper §3.3).
//!
//! `l(i,j)` stores the exact distance computed at round `T(i,j)`; the
//! effective bound this round is `l(i,j) − P(j, T(i,j))` (lower) and
//! `u(i) + P(a(i), T(i,a(i)))` (upper). A bound is *tight* exactly when
//! its `T` is the current round.

use crate::algorithms::common::{
    batch_scan, dist_ic, AssignStep, Moved, Requirements, SharedRound,
};
use crate::data::source::BlockCursor;
use crate::metrics::Counters;

/// selk-ns per-sample state.
pub struct SelkNs {
    lo: usize,
    k: usize,
    /// Exact distance to the assigned centroid at epoch round `tu`.
    u: Vec<f64>,
    /// Epoch round at which `u` was computed.
    tu: Vec<u32>,
    /// Exact distances `‖x(i) − c_T(j)‖`, row-major `len×k`.
    l: Vec<f64>,
    /// Epoch round of each `l` entry, row-major `len×k`.
    tl: Vec<u32>,
}

impl SelkNs {
    /// Create for a shard `[lo, lo+len)` with `k` clusters.
    pub fn new(lo: usize, len: usize, k: usize) -> Self {
        SelkNs {
            lo,
            k,
            u: vec![0.0; len],
            tu: vec![0; len],
            l: vec![0.0; len * k],
            tl: vec![0; len * k],
        }
    }
}

impl AssignStep for SelkNs {
    fn name(&self) -> &'static str {
        "selk-ns"
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn requirements(&self) -> Requirements {
        Requirements {
            history: true,
            ..Requirements::default()
        }
    }

    fn init(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
    ) {
        let lo = self.lo;
        let k = self.k;
        let (u, l) = (&mut self.u, &mut self.l);
        batch_scan(sh, rows, lo, lo + a.len(), ctr, |li, row| {
            let lrow = &mut l[li * k..(li + 1) * k];
            let mut best = 0usize;
            let mut bd = f64::INFINITY;
            for (j, &sq) in row.iter().enumerate() {
                let dj = sq.sqrt();
                lrow[j] = dj;
                if dj < bd {
                    bd = dj;
                    best = j;
                }
            }
            a[li] = best as u32;
            u[li] = bd;
        });
        // T arrays already zero == epoch round 0 (everything tight)
    }

    fn round(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
        moved: &mut Vec<Moved>,
    ) {
        let lo = self.lo;
        let k = self.k;
        let h = sh.history.expect("ns variant requires history");
        let ep = &h.epoch;
        let t_now = (ep.len - 1) as u32;
        for (li, a_li) in a.iter_mut().enumerate() {
            let gi = lo + li;
            let a0 = *a_li as usize;
            let mut ai = a0;
            let lrow = &mut self.l[li * k..(li + 1) * k];
            let tlrow = &mut self.tl[li * k..(li + 1) * k];
            // sn-style reset fold (paper §3.3 end)
            if let Some(fold) = &h.fold {
                self.u[li] += fold.p(ai, self.tu[li] as usize);
                self.tu[li] = 0;
                for j in 0..k {
                    lrow[j] -= fold.p(j, tlrow[j] as usize);
                    tlrow[j] = 0;
                }
            }
            let mut eu = self.u[li] + ep.p(ai, self.tu[li] as usize);
            for j in 0..k {
                if j == ai {
                    continue;
                }
                let el = lrow[j] - ep.p(j, tlrow[j] as usize);
                if el >= eu {
                    continue;
                }
                if self.tu[li] != t_now {
                    // tighten u
                    ctr.assignment += 1;
                    let du = crate::linalg::sqdist(rows.row(gi), sh.centroid(ai)).sqrt();
                    self.u[li] = du;
                    self.tu[li] = t_now;
                    eu = du;
                    if el >= eu {
                        continue;
                    }
                }
                // tighten l(i,j)
                lrow[j] = dist_ic(sh, rows, gi, j, ctr);
                tlrow[j] = t_now;
                if lrow[j] < eu {
                    // both tight: j is strictly nearer. Keep the old
                    // assignee's exact record as its l entry.
                    lrow[ai] = self.u[li];
                    tlrow[ai] = self.tu[li];
                    ai = j;
                    self.u[li] = lrow[j];
                    self.tu[li] = t_now;
                    eu = lrow[j];
                }
            }
            if ai != a0 {
                moved.push(Moved {
                    i: gi as u32,
                    from: a0 as u32,
                    to: ai as u32,
                });
                *a_li = ai as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::*;

    #[test]
    fn matches_sta_on_blobs() {
        assert_exact_vs_sta(
            |lo, len, k, _g| Box::new(SelkNs::new(lo, len, k)),
            400,
            8,
            10,
            61,
        );
    }

    #[test]
    fn matches_sta_with_history_resets() {
        // tiny reset cap exercises the fold path (set in testutil)
        assert_exact_vs_sta_with_reset(
            |lo, len, k, _g| Box::new(SelkNs::new(lo, len, k)),
            300,
            5,
            8,
            67,
            3, // reset every 3 rounds
        );
    }

    #[test]
    fn bounds_remain_valid_every_round() {
        assert_bounds_valid(
            |lo, len, k, _g| Box::new(SelkNs::new(lo, len, k)),
            |alg, chk| {
                let s = alg.as_any().downcast_ref::<SelkNs>().unwrap();
                let ep = chk.epoch().expect("history");
                for li in 0..chk.len() {
                    let ai = chk.assignment(li) as usize;
                    chk.upper(li, s.u[li] + ep.p(ai, s.tu[li] as usize));
                    for j in 0..s.k {
                        let el = s.l[li * s.k + j] - ep.p(j, s.tl[li * s.k + j] as usize);
                        chk.lower_per(li, j, el);
                    }
                }
            },
        );
    }
}
