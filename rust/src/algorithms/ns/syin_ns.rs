//! `syin-ns` — Simplified Yinyang with ns group bounds (paper §3.4,
//! SM-C.2's MNS scheme): the stored group bound is the exact group
//! minimum at round `T_l(i,f)`; the effective bound subtracts
//! `max_{j∈G(f)} P(j, T_l(i,f))` from the epoch's per-group tables.

use crate::algorithms::common::{
    batch_scan, dist_ic, AssignStep, Moved, Requirements, SharedRound,
};
use crate::data::source::BlockCursor;
use crate::linalg::Top2;
use crate::metrics::Counters;

/// syin-ns per-sample state.
pub struct SyinNs {
    lo: usize,
    g: usize,
    u: Vec<f64>,
    tu: Vec<u32>,
    /// Group bound bases, row-major `len×g`.
    l: Vec<f64>,
    tl: Vec<u32>,
    // scratch
    gmin: Vec<Top2>,
    scanned: Vec<bool>,
    el: Vec<f64>,
}

impl SyinNs {
    /// Create for a shard `[lo, lo+len)` with `g` groups.
    pub fn new(lo: usize, len: usize, g: usize) -> Self {
        SyinNs {
            lo,
            g,
            u: vec![0.0; len],
            tu: vec![0; len],
            l: vec![0.0; len * g],
            tl: vec![0; len * g],
            gmin: vec![Top2::new(); g],
            scanned: vec![false; g],
            el: vec![0.0; g],
        }
    }
}

impl AssignStep for SyinNs {
    fn name(&self) -> &'static str {
        "syin-ns"
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn requirements(&self) -> Requirements {
        Requirements {
            groups: true,
            history: true,
            group_history: true,
            ..Requirements::default()
        }
    }

    fn init(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
    ) {
        let lo = self.lo;
        let g = self.g;
        let gd = sh.groups.expect("syin-ns requires groups");
        let (u, l) = (&mut self.u, &mut self.l);
        let mut gms = vec![Top2::new(); g];
        batch_scan(sh, rows, lo, lo + a.len(), ctr, |li, row| {
            for gm in gms.iter_mut() {
                *gm = Top2::new();
            }
            let mut best = Top2::new();
            for (j, &sq) in row.iter().enumerate() {
                let dj = sq.sqrt();
                gms[gd.group_of[j] as usize].push(j, dj);
                best.push(j, dj);
            }
            let ai = best.idx1;
            a[li] = ai as u32;
            u[li] = best.val1;
            let lrow = &mut l[li * g..(li + 1) * g];
            for (f, gm) in gms.iter().enumerate() {
                lrow[f] = if gm.idx1 == ai { gm.val2 } else { gm.val1 };
            }
        });
    }

    fn round(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
        moved: &mut Vec<Moved>,
    ) {
        let lo = self.lo;
        let g = self.g;
        let gd = sh.groups.expect("syin-ns requires groups");
        let h = sh.history.expect("ns variant requires history");
        let ep = &h.epoch;
        let t_now = (ep.len - 1) as u32;
        for (li, a_li) in a.iter_mut().enumerate() {
            let gi = lo + li;
            let a0 = *a_li as usize;
            let lrow = &mut self.l[li * g..(li + 1) * g];
            let tlrow = &mut self.tl[li * g..(li + 1) * g];
            if let Some(fold) = &h.fold {
                self.u[li] += fold.p(a0, self.tu[li] as usize);
                self.tu[li] = 0;
                for f in 0..g {
                    lrow[f] -= fold.group_max(f, tlrow[f] as usize);
                    tlrow[f] = 0;
                }
            }
            let mut eu = self.u[li] + ep.p(a0, self.tu[li] as usize);
            let mut minl = f64::INFINITY;
            for f in 0..g {
                let e = lrow[f] - ep.group_max(f, tlrow[f] as usize);
                self.el[f] = e;
                if e < minl {
                    minl = e;
                }
            }
            // outer test (eq. 10)
            if minl >= eu {
                continue;
            }
            if self.tu[li] != t_now {
                ctr.assignment += 1;
                eu = crate::linalg::sqdist(rows.row(gi), sh.centroid(a0)).sqrt();
                self.u[li] = eu;
                self.tu[li] = t_now;
            }
            let d_old = eu; // tight distance to the old assignee
            if minl >= d_old {
                continue;
            }
            let f_old = gd.group_of[a0] as usize;
            let mut best = Top2::new();
            best.push(a0, d_old);
            for f in 0..g {
                let scan = self.el[f] < best.val1;
                self.scanned[f] = scan;
                if !scan {
                    continue;
                }
                let mut gm = Top2::new();
                if f == f_old {
                    gm.push(a0, d_old);
                }
                for &j in &gd.members[f] {
                    let j = j as usize;
                    if j == a0 {
                        continue;
                    }
                    let dj = dist_ic(sh, rows, gi, j, ctr);
                    gm.push(j, dj);
                    best.push(j, dj);
                }
                self.gmin[f] = gm;
            }
            let a_new = best.idx1;
            self.u[li] = best.val1;
            self.tu[li] = t_now;
            for f in 0..g {
                if self.scanned[f] {
                    let gm = &self.gmin[f];
                    lrow[f] = if gm.idx1 == a_new { gm.val2 } else { gm.val1 };
                    tlrow[f] = t_now;
                } else if f == f_old && a_new != a0 {
                    // old assignee joins this group's bound set with a
                    // known exact distance vs the *current* centroids
                    lrow[f] = self.el[f].min(d_old);
                    tlrow[f] = t_now;
                }
            }
            if a_new != a0 {
                moved.push(Moved {
                    i: gi as u32,
                    from: a0 as u32,
                    to: a_new as u32,
                });
                *a_li = a_new as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::*;

    #[test]
    fn matches_sta_on_blobs() {
        assert_exact_vs_sta(
            |lo, len, _k, g| Box::new(SyinNs::new(lo, len, g)),
            500,
            10,
            20,
            97,
        );
    }

    #[test]
    fn matches_sta_many_clusters() {
        assert_exact_vs_sta(
            |lo, len, _k, g| Box::new(SyinNs::new(lo, len, g)),
            600,
            6,
            40,
            101,
        );
    }

    #[test]
    fn matches_sta_with_history_resets() {
        assert_exact_vs_sta_with_reset(
            |lo, len, _k, g| Box::new(SyinNs::new(lo, len, g)),
            300,
            5,
            12,
            103,
            3,
        );
    }

    #[test]
    fn bounds_remain_valid_every_round() {
        assert_bounds_valid(
            |lo, len, _k, g| Box::new(SyinNs::new(lo, len, g)),
            |alg, chk| {
                let s = alg.as_any().downcast_ref::<SyinNs>().unwrap();
                let ep = chk.epoch().expect("history");
                for li in 0..chk.len() {
                    let ai = chk.assignment(li) as usize;
                    chk.upper(li, s.u[li] + ep.p(ai, s.tu[li] as usize));
                    for f in 0..s.g {
                        let el = s.l[li * s.g + f] - ep.group_max(f, s.tl[li * s.g + f] as usize);
                        chk.lower_group(li, f, el);
                    }
                }
            },
        );
    }
}
