//! `elk-ns` — Elkan's algorithm with ns-bounds (paper §3.4): selk-ns plus
//! the inter-centroid tests (outer eq. 7, inner eq. 6) evaluated against
//! the ns effective bounds.

use crate::algorithms::common::{
    batch_scan, dist_ic, AssignStep, Moved, Requirements, SharedRound,
};
use crate::data::source::BlockCursor;
use crate::metrics::Counters;

/// elk-ns per-sample state (same shape as selk-ns).
pub struct ElkNs {
    lo: usize,
    k: usize,
    u: Vec<f64>,
    tu: Vec<u32>,
    l: Vec<f64>,
    tl: Vec<u32>,
}

impl ElkNs {
    /// Create for a shard `[lo, lo+len)` with `k` clusters.
    pub fn new(lo: usize, len: usize, k: usize) -> Self {
        ElkNs {
            lo,
            k,
            u: vec![0.0; len],
            tu: vec![0; len],
            l: vec![0.0; len * k],
            tl: vec![0; len * k],
        }
    }
}

impl AssignStep for ElkNs {
    fn name(&self) -> &'static str {
        "elk-ns"
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn requirements(&self) -> Requirements {
        Requirements {
            cc: true,
            history: true,
            ..Requirements::default()
        }
    }

    fn init(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
    ) {
        let lo = self.lo;
        let k = self.k;
        let (u, l) = (&mut self.u, &mut self.l);
        batch_scan(sh, rows, lo, lo + a.len(), ctr, |li, row| {
            let lrow = &mut l[li * k..(li + 1) * k];
            let mut best = 0usize;
            let mut bd = f64::INFINITY;
            for (j, &sq) in row.iter().enumerate() {
                let dj = sq.sqrt();
                lrow[j] = dj;
                if dj < bd {
                    bd = dj;
                    best = j;
                }
            }
            a[li] = best as u32;
            u[li] = bd;
        });
    }

    fn round(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
        moved: &mut Vec<Moved>,
    ) {
        let lo = self.lo;
        let k = self.k;
        let cc = sh.cc.expect("elk-ns requires cc");
        let h = sh.history.expect("ns variant requires history");
        let ep = &h.epoch;
        let t_now = (ep.len - 1) as u32;
        for (li, a_li) in a.iter_mut().enumerate() {
            let gi = lo + li;
            let a0 = *a_li as usize;
            let mut ai = a0;
            let lrow = &mut self.l[li * k..(li + 1) * k];
            let tlrow = &mut self.tl[li * k..(li + 1) * k];
            if let Some(fold) = &h.fold {
                self.u[li] += fold.p(ai, self.tu[li] as usize);
                self.tu[li] = 0;
                for j in 0..k {
                    lrow[j] -= fold.p(j, tlrow[j] as usize);
                    tlrow[j] = 0;
                }
            }
            let mut eu = self.u[li] + ep.p(ai, self.tu[li] as usize);
            // outer test (eq. 7)
            if cc.s[ai] * 0.5 >= eu {
                continue;
            }
            for j in 0..k {
                if j == ai || cc.get(ai, j) * 0.5 >= eu {
                    continue;
                }
                let el = lrow[j] - ep.p(j, tlrow[j] as usize);
                if el >= eu {
                    continue;
                }
                if self.tu[li] != t_now {
                    ctr.assignment += 1;
                    let du = crate::linalg::sqdist(rows.row(gi), sh.centroid(ai)).sqrt();
                    self.u[li] = du;
                    self.tu[li] = t_now;
                    eu = du;
                    if el >= eu || cc.get(ai, j) * 0.5 >= eu {
                        continue;
                    }
                }
                lrow[j] = dist_ic(sh, rows, gi, j, ctr);
                tlrow[j] = t_now;
                if lrow[j] < eu {
                    lrow[ai] = self.u[li];
                    tlrow[ai] = self.tu[li];
                    ai = j;
                    self.u[li] = lrow[j];
                    self.tu[li] = t_now;
                    eu = lrow[j];
                }
            }
            if ai != a0 {
                moved.push(Moved {
                    i: gi as u32,
                    from: a0 as u32,
                    to: ai as u32,
                });
                *a_li = ai as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::*;

    #[test]
    fn matches_sta_on_blobs() {
        assert_exact_vs_sta(|lo, len, k, _g| Box::new(ElkNs::new(lo, len, k)), 400, 8, 10, 71);
    }

    #[test]
    fn matches_sta_with_history_resets() {
        assert_exact_vs_sta_with_reset(
            |lo, len, k, _g| Box::new(ElkNs::new(lo, len, k)),
            300,
            12,
            8,
            73,
            3,
        );
    }

    #[test]
    fn bounds_remain_valid_every_round() {
        assert_bounds_valid(
            |lo, len, k, _g| Box::new(ElkNs::new(lo, len, k)),
            |alg, chk| {
                let s = alg.as_any().downcast_ref::<ElkNs>().unwrap();
                let ep = chk.epoch().expect("history");
                for li in 0..chk.len() {
                    let ai = chk.assignment(li) as usize;
                    chk.upper(li, s.u[li] + ep.p(ai, s.tu[li] as usize));
                    for j in 0..s.k {
                        let el = s.l[li * s.k + j] - ep.p(j, s.tl[li * s.k + j] as usize);
                        chk.lower_per(li, j, el);
                    }
                }
            },
        );
    }
}
