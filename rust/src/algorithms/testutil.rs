//! Test utilities shared by algorithm and data-source tests: exactness
//! versus sta, bound-validity checking, and the block-lease contract
//! property suite every [`DataSource`] implementation must pass.
//! (Compiled into the library so integration tests — which exercise the
//! out-of-core sources against real files — reuse the same harness.)

use crate::algorithms::common::AssignStep;
use crate::algorithms::Algorithm;
use crate::config::RunConfig;
use crate::coordinator::history::Epoch;
use crate::coordinator::parallel::make_shards;
use crate::coordinator::runner::Engine;
use crate::coordinator::sched::{ScanPlan, MIN_SHARD_ROWS};
use crate::data::synth::blobs;
use crate::data::{DataSource, Dataset};
use crate::linalg::{sqdist, sqnorm};
use crate::proptest::forall;

/// Factory signature used by the helpers.
pub type Factory = dyn Fn(usize, usize, usize, usize) -> Box<dyn AssignStep>;

const EPS: f64 = 1e-7;

/// Run `factory`'s algorithm and sta in lockstep on gaussian blobs and
/// assert per-round assignment equality — the paper's exactness property.
pub fn assert_exact_vs_sta(factory: impl Fn(usize, usize, usize, usize) -> Box<dyn AssignStep>, n: usize, d: usize, k: usize, seed: u64) {
    assert_exact_vs_sta_with_reset(factory, n, d, k, seed, usize::MAX);
}

/// As [`assert_exact_vs_sta`] but with a forced ns history reset period
/// (exercises the fold path).
pub fn assert_exact_vs_sta_with_reset(
    factory: impl Fn(usize, usize, usize, usize) -> Box<dyn AssignStep>,
    n: usize,
    d: usize,
    k: usize,
    seed: u64,
    history_cap: usize,
) {
    let ds = blobs(n, d, k, 0.25, seed);
    let mut cfg = RunConfig::new(Algorithm::Sta, k).seed(seed).max_iters(200);
    if history_cap != usize::MAX {
        cfg.history_cap = Some(history_cap.max(2));
    }
    let mut sta = Engine::new(&ds, &cfg).unwrap();
    let mut alg = Engine::with_factory(&ds, &cfg, &factory).unwrap();
    assert_eq!(
        sta.assignments(),
        alg.assignments(),
        "initial assignment differs ({})",
        alg.name()
    );
    for round in 1..=200 {
        let ms = sta.step();
        let ma = alg.step();
        assert_eq!(
            sta.assignments(),
            alg.assignments(),
            "round {round}: assignments diverge ({})",
            alg.name()
        );
        assert_eq!(
            ms,
            ma,
            "round {round}: move counts differ ({})",
            alg.name()
        );
        if sta.converged() || alg.converged() {
            assert_eq!(sta.converged(), alg.converged(), "convergence differs");
            break;
        }
    }
    assert!(sta.converged(), "did not converge within 200 rounds");
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Property suite for the block-lease [`DataSource`] contract (the
/// invariants listed in [`data::source`](crate::data::source)'s module
/// docs), shared by every implementation — `Dataset`, `BatchView`,
/// `MmapSource`, `ChunkedFileSource`:
///
/// 1. **coverage** — for several shard widths, walking each shard's
///    cursor with randomized lease sizes tiles exactly `[lo, lo+len)`
///    in order and reproduces the reference bytes;
/// 2. **stability** — every lease (including re-reads and backward
///    random access) observes the same bits as the reference read;
/// 3. **norms match rows** — leased `sqnorms` equal
///    [`sqnorm`](crate::linalg::sqnorm) of the leased rows bit-for-bit.
///
/// Panics (via the mini-proptest harness, with a reproducing case
/// index) on the first violation.
pub fn assert_block_lease_contract(src: &dyn DataSource, seed: u64) {
    let (n, d) = (src.n(), src.d());
    assert!(n > 0 && d > 0, "contract harness needs a non-empty source");

    // reference read: one lease of everything
    let (reference, ref_norms) = {
        let mut cur = src.open(0, n);
        let block = cur.lease(0, n);
        (block.rows().to_vec(), block.sqnorms().to_vec())
    };
    assert_eq!(reference.len(), n * d);
    assert_eq!(ref_norms.len(), n);
    for i in 0..n {
        assert_eq!(
            ref_norms[i].to_bits(),
            sqnorm(&reference[i * d..(i + 1) * d]).to_bits(),
            "norm of row {i} does not match its rows bit-for-bit"
        );
    }

    // coverage + stability over sharded, randomized block walks
    forall(seed, 12, |g| {
        let w = g.usize_in(1, 4);
        for (lo, len) in make_shards(n, w) {
            let mut cur = src.open(lo, len);
            let mut rows = Vec::with_capacity(len * d);
            let mut norms = Vec::with_capacity(len);
            let mut at = lo;
            while at < lo + len {
                let take = g.usize_in(1, 64).min(lo + len - at);
                let block = cur.lease(at, take);
                assert_eq!(block.lo(), at);
                assert_eq!(block.len(), take);
                assert_eq!(block.d(), d);
                rows.extend_from_slice(block.rows());
                norms.extend_from_slice(block.sqnorms());
                at += take;
            }
            assert_eq!(
                bits(&rows),
                bits(&reference[lo * d..(lo + len) * d]),
                "shard [{lo}, {}) rows diverge from the reference read",
                lo + len
            );
            assert_eq!(bits(&norms), bits(&ref_norms[lo..lo + len]));
        }
    });

    // random access through one cursor: forward, backward, repeated
    forall(seed ^ 0x9E37_79B9, 6, |g| {
        let mut cur = src.open(0, n);
        for _ in 0..40 {
            let i = g.usize_in(0, n - 1);
            assert_eq!(
                bits(cur.row(i)),
                bits(&reference[i * d..(i + 1) * d]),
                "random-access row {i} unstable"
            );
            assert_eq!(cur.sqnorm(i).to_bits(), ref_norms[i].to_bits());
        }
    });
}

/// Assert the [`ScanPlan`] geometry invariants for `n` rows under
/// `spec` (a `--scan-shards` value; `AUTO_SCAN_SHARDS` for auto):
///
/// 1. **cover** — shard lengths sum to `n` and tile `[0, n)` contiguously
///    in ascending order (the merge-order contract);
/// 2. **floor** — every shard spans at least
///    [`MIN_SHARD_ROWS`](crate::coordinator::sched::MIN_SHARD_ROWS) rows
///    whenever `n` itself does (ooc cursors never window-thrash);
/// 3. **order** — the claim order is a permutation of the shard indices.
pub fn assert_scan_plan_invariants(n: usize, spec: usize) {
    let plan = ScanPlan::for_rows(n, spec);
    let shards = plan.shards();
    let total: usize = shards.iter().map(|s| s.1).sum();
    assert_eq!(total, n, "shards of ({n}, {spec}) do not cover n rows");
    let mut at = 0;
    for &(lo, len) in shards {
        assert_eq!(lo, at, "shards of ({n}, {spec}) are not contiguous");
        assert!(
            len >= MIN_SHARD_ROWS || shards.len() == 1,
            "shard of ({n}, {spec}) spans {len} rows, below the floor"
        );
        at += len;
    }
    let mut seen = vec![false; shards.len()];
    for &i in plan.order() {
        assert!(!seen[i], "claim order of ({n}, {spec}) repeats shard {i}");
        seen[i] = true;
    }
    assert!(seen.iter().all(|&s| s), "claim order of ({n}, {spec}) is not a permutation");
    assert_eq!(plan.telemetry().shards, shards.len());
}

/// Bound inspection context handed to per-algorithm checkers.
pub struct BoundCheck<'a> {
    data: &'a Dataset,
    centroids: &'a [f64],
    a: &'a [u32],
    groups: Option<&'a crate::coordinator::groups::GroupData>,
    epoch: Option<&'a Epoch>,
    round: usize,
}

impl<'a> BoundCheck<'a> {
    /// Number of samples (single shard in these tests).
    pub fn len(&self) -> usize {
        self.a.len()
    }

    /// There is always at least one sample.
    pub fn is_empty(&self) -> bool {
        self.a.is_empty()
    }

    /// Current assignment of sample `li`.
    pub fn assignment(&self, li: usize) -> u32 {
        self.a[li]
    }

    /// ns epoch (None for sn algorithms).
    pub fn epoch(&self) -> Option<&'a Epoch> {
        self.epoch
    }

    fn dist(&self, li: usize, j: usize) -> f64 {
        let d = self.data.d();
        sqdist(self.data.row(li), &self.centroids[j * d..(j + 1) * d]).sqrt()
    }

    /// Assert `u` is a valid upper bound on `‖x − c(a)‖`.
    pub fn upper(&self, li: usize, u: f64) {
        let true_d = self.dist(li, self.a[li] as usize);
        assert!(
            u >= true_d - EPS,
            "round {}: sample {li}: upper bound {u} < true {true_d}",
            self.round
        );
    }

    /// Assert `l` lower-bounds `min_{j≠a} ‖x − c(j)‖`.
    pub fn lower_all(&self, li: usize, l: f64) {
        let ai = self.a[li] as usize;
        let k = self.centroids.len() / self.data.d();
        let mut mn = f64::INFINITY;
        for j in 0..k {
            if j != ai {
                mn = mn.min(self.dist(li, j));
            }
        }
        assert!(
            l <= mn + EPS,
            "round {}: sample {li}: global lower {l} > true min {mn}",
            self.round
        );
    }

    /// Assert `l` lower-bounds `‖x − c(j)‖`.
    pub fn lower_per(&self, li: usize, j: usize, l: f64) {
        let true_d = self.dist(li, j);
        assert!(
            l <= true_d + EPS,
            "round {}: sample {li}, j={j}: lower {l} > true {true_d}",
            self.round
        );
    }

    /// Assert `l` lower-bounds `min_{j ∈ G(f)\{a}} ‖x − c(j)‖`.
    pub fn lower_group(&self, li: usize, f: usize, l: f64) {
        let gd = self.groups.expect("group check without groups");
        let ai = self.a[li];
        let mut mn = f64::INFINITY;
        for &j in &gd.members[f] {
            if j != ai {
                mn = mn.min(self.dist(li, j as usize));
            }
        }
        assert!(
            l <= mn + EPS,
            "round {}: sample {li}, group {f}: lower {l} > true min {mn}",
            self.round
        );
    }

    /// Assert ann's `b(i)` differs from `a(i)` and is in range.
    pub fn b_differs(&self, li: usize, b: u32) {
        let k = (self.centroids.len() / self.data.d()) as u32;
        assert!(b < k, "b out of range");
        assert_ne!(b, self.a[li], "b(i) == a(i)");
    }
}

/// Run an engine for up to 60 rounds on blobs, invoking `inspect` after
/// every round so algorithm tests can validate their bound state.
pub fn assert_bounds_valid(
    factory: impl Fn(usize, usize, usize, usize) -> Box<dyn AssignStep>,
    inspect: impl Fn(&dyn AssignStep, &BoundCheck),
) {
    let (n, d, k, seed) = (300, 5, 12, 5u64);
    let ds = blobs(n, d, k, 0.3, seed);
    let mut cfg = RunConfig::new(Algorithm::Sta, k).seed(seed);
    cfg.history_cap = Some(4); // force folds so ns bounds get exercised
    let mut engine = Engine::with_factory(&ds, &cfg, &factory).unwrap();
    for round in 1..=60 {
        if engine.converged() {
            break;
        }
        engine.step();
        let ctx = engine.ctx();
        let chk = BoundCheck {
            data: &ds,
            centroids: &ctx.centroids,
            a: engine.assignments(),
            groups: ctx.groups.as_ref(),
            epoch: ctx.history.as_ref().map(|h| &h.epoch),
            round,
        };
        inspect(engine.algs()[0].as_ref(), &chk);
    }
    assert!(engine.converged(), "bounds test run did not converge");
}
