//! Test utilities shared by every algorithm's unit tests: exactness
//! versus sta and bound-validity checking.

use crate::algorithms::common::AssignStep;
use crate::algorithms::Algorithm;
use crate::config::RunConfig;
use crate::coordinator::history::Epoch;
use crate::coordinator::runner::Engine;
use crate::data::synth::blobs;
use crate::data::Dataset;
use crate::linalg::sqdist;

/// Factory signature used by the helpers.
pub type Factory = dyn Fn(usize, usize, usize, usize) -> Box<dyn AssignStep>;

const EPS: f64 = 1e-7;

/// Run `factory`'s algorithm and sta in lockstep on gaussian blobs and
/// assert per-round assignment equality — the paper's exactness property.
pub fn assert_exact_vs_sta(factory: impl Fn(usize, usize, usize, usize) -> Box<dyn AssignStep>, n: usize, d: usize, k: usize, seed: u64) {
    assert_exact_vs_sta_with_reset(factory, n, d, k, seed, usize::MAX);
}

/// As [`assert_exact_vs_sta`] but with a forced ns history reset period
/// (exercises the fold path).
pub fn assert_exact_vs_sta_with_reset(
    factory: impl Fn(usize, usize, usize, usize) -> Box<dyn AssignStep>,
    n: usize,
    d: usize,
    k: usize,
    seed: u64,
    history_cap: usize,
) {
    let ds = blobs(n, d, k, 0.25, seed);
    let mut cfg = RunConfig::new(Algorithm::Sta, k).seed(seed).max_iters(200);
    if history_cap != usize::MAX {
        cfg.history_cap = Some(history_cap.max(2));
    }
    let mut sta = Engine::new(&ds, &cfg).unwrap();
    let mut alg = Engine::with_factory(&ds, &cfg, &factory).unwrap();
    assert_eq!(
        sta.assignments(),
        alg.assignments(),
        "initial assignment differs ({})",
        alg.name()
    );
    for round in 1..=200 {
        let ms = sta.step();
        let ma = alg.step();
        assert_eq!(
            sta.assignments(),
            alg.assignments(),
            "round {round}: assignments diverge ({})",
            alg.name()
        );
        assert_eq!(
            ms,
            ma,
            "round {round}: move counts differ ({})",
            alg.name()
        );
        if sta.converged() || alg.converged() {
            assert_eq!(sta.converged(), alg.converged(), "convergence differs");
            break;
        }
    }
    assert!(sta.converged(), "did not converge within 200 rounds");
}

/// Bound inspection context handed to per-algorithm checkers.
pub struct BoundCheck<'a> {
    data: &'a Dataset,
    centroids: &'a [f64],
    a: &'a [u32],
    groups: Option<&'a crate::coordinator::groups::GroupData>,
    epoch: Option<&'a Epoch>,
    round: usize,
}

impl<'a> BoundCheck<'a> {
    /// Number of samples (single shard in these tests).
    pub fn len(&self) -> usize {
        self.a.len()
    }

    /// There is always at least one sample.
    pub fn is_empty(&self) -> bool {
        self.a.is_empty()
    }

    /// Current assignment of sample `li`.
    pub fn assignment(&self, li: usize) -> u32 {
        self.a[li]
    }

    /// ns epoch (None for sn algorithms).
    pub fn epoch(&self) -> Option<&'a Epoch> {
        self.epoch
    }

    fn dist(&self, li: usize, j: usize) -> f64 {
        let d = self.data.d();
        sqdist(self.data.row(li), &self.centroids[j * d..(j + 1) * d]).sqrt()
    }

    /// Assert `u` is a valid upper bound on `‖x − c(a)‖`.
    pub fn upper(&self, li: usize, u: f64) {
        let true_d = self.dist(li, self.a[li] as usize);
        assert!(
            u >= true_d - EPS,
            "round {}: sample {li}: upper bound {u} < true {true_d}",
            self.round
        );
    }

    /// Assert `l` lower-bounds `min_{j≠a} ‖x − c(j)‖`.
    pub fn lower_all(&self, li: usize, l: f64) {
        let ai = self.a[li] as usize;
        let k = self.centroids.len() / self.data.d();
        let mut mn = f64::INFINITY;
        for j in 0..k {
            if j != ai {
                mn = mn.min(self.dist(li, j));
            }
        }
        assert!(
            l <= mn + EPS,
            "round {}: sample {li}: global lower {l} > true min {mn}",
            self.round
        );
    }

    /// Assert `l` lower-bounds `‖x − c(j)‖`.
    pub fn lower_per(&self, li: usize, j: usize, l: f64) {
        let true_d = self.dist(li, j);
        assert!(
            l <= true_d + EPS,
            "round {}: sample {li}, j={j}: lower {l} > true {true_d}",
            self.round
        );
    }

    /// Assert `l` lower-bounds `min_{j ∈ G(f)\{a}} ‖x − c(j)‖`.
    pub fn lower_group(&self, li: usize, f: usize, l: f64) {
        let gd = self.groups.expect("group check without groups");
        let ai = self.a[li];
        let mut mn = f64::INFINITY;
        for &j in &gd.members[f] {
            if j != ai {
                mn = mn.min(self.dist(li, j as usize));
            }
        }
        assert!(
            l <= mn + EPS,
            "round {}: sample {li}, group {f}: lower {l} > true min {mn}",
            self.round
        );
    }

    /// Assert ann's `b(i)` differs from `a(i)` and is in range.
    pub fn b_differs(&self, li: usize, b: u32) {
        let k = (self.centroids.len() / self.data.d()) as u32;
        assert!(b < k, "b out of range");
        assert_ne!(b, self.a[li], "b(i) == a(i)");
    }
}

/// Run an engine for up to 60 rounds on blobs, invoking `inspect` after
/// every round so algorithm tests can validate their bound state.
pub fn assert_bounds_valid(
    factory: impl Fn(usize, usize, usize, usize) -> Box<dyn AssignStep>,
    inspect: impl Fn(&dyn AssignStep, &BoundCheck),
) {
    let (n, d, k, seed) = (300, 5, 12, 5u64);
    let ds = blobs(n, d, k, 0.3, seed);
    let mut cfg = RunConfig::new(Algorithm::Sta, k).seed(seed);
    cfg.history_cap = Some(4); // force folds so ns bounds get exercised
    let mut engine = Engine::with_factory(&ds, &cfg, &factory).unwrap();
    for round in 1..=60 {
        if engine.converged() {
            break;
        }
        engine.step();
        let ctx = engine.ctx();
        let chk = BoundCheck {
            data: &ds,
            centroids: &ctx.centroids,
            a: engine.assignments(),
            groups: ctx.groups.as_ref(),
            epoch: ctx.history.as_ref().map(|h| &h.epoch),
            round,
        };
        inspect(engine.algs()[0].as_ref(), &chk);
    }
    assert!(engine.converged(), "bounds test run did not converge");
}
