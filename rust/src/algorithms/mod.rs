//! All k-means assignment-step algorithms the paper evaluates, behind a
//! single [`Algorithm`] registry.

pub mod ann;
pub mod common;
pub mod elk;
pub mod exponion;
pub mod ham;
pub mod naive;
pub mod ns;
pub mod selk;
pub mod sta;
pub mod testutil;
pub mod yinyang;

pub use common::{AssignStep, Moved, Requirements, SharedRound};

/// Every algorithm variant the crate implements (paper notation).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Standard Lloyd's algorithm — every distance, every round.
    Sta,
    /// Simplified Elkan: k lower bounds, no inter-centroid tests.
    Selk,
    /// Elkan 2003: k lower bounds plus inter-centroid tests.
    Elk,
    /// Hamerly 2010: one lower bound with an outer test.
    Ham,
    /// Drake 2013 Annular: origin-centred norm annulus over Hamerly.
    Ann,
    /// **Exponion** (this paper §3.1): centroid-centred ball over
    /// Hamerly.
    Exp,
    /// Simplified Yinyang: group bounds, no local filter.
    Syin,
    /// Yinyang (Ding et al. 2015), with the local filter.
    Yin,
    /// [`Selk`](Algorithm::Selk) with ns-bounds (this paper §3.2).
    SelkNs,
    /// [`Elk`](Algorithm::Elk) with ns-bounds (this paper §3.2).
    ElkNs,
    /// [`Syin`](Algorithm::Syin) with ns-bounds (this paper §3.2).
    SyinNs,
    /// [`Exp`](Algorithm::Exp) with ns-bounds (this paper §3.2).
    ExpNs,
    // Table 7 comparator family (deliberately less engineered)
    /// Table 7 comparator: Lloyd's without the engineering of §4.1.1.
    NaiveSta,
    /// Table 7 comparator: unengineered Hamerly.
    NaiveHam,
    /// Table 7 comparator: unengineered Elkan.
    NaiveElk,
    /// Table 7 comparator: unengineered Yinyang.
    NaiveYin,
    /// Adaptive choice by dimension (paper §5 future work; see
    /// `coordinator::auto`).
    Auto,
}

impl Algorithm {
    /// The paper's sn-algorithms (Table 4 candidates).
    pub const SN: [Algorithm; 8] = [
        Algorithm::Sta,
        Algorithm::Selk,
        Algorithm::Elk,
        Algorithm::Ham,
        Algorithm::Ann,
        Algorithm::Exp,
        Algorithm::Syin,
        Algorithm::Yin,
    ];

    /// The ns-variants (paper §3.4).
    pub const NS: [Algorithm; 4] = [
        Algorithm::SelkNs,
        Algorithm::ElkNs,
        Algorithm::SyinNs,
        Algorithm::ExpNs,
    ];

    /// Everything that can actually run (excludes `Auto`).
    pub const ALL: [Algorithm; 16] = [
        Algorithm::Sta,
        Algorithm::Selk,
        Algorithm::Elk,
        Algorithm::Ham,
        Algorithm::Ann,
        Algorithm::Exp,
        Algorithm::Syin,
        Algorithm::Yin,
        Algorithm::SelkNs,
        Algorithm::ElkNs,
        Algorithm::SyinNs,
        Algorithm::ExpNs,
        Algorithm::NaiveSta,
        Algorithm::NaiveHam,
        Algorithm::NaiveElk,
        Algorithm::NaiveYin,
    ];

    /// Paper-notation name.
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Sta => "sta",
            Algorithm::Selk => "selk",
            Algorithm::Elk => "elk",
            Algorithm::Ham => "ham",
            Algorithm::Ann => "ann",
            Algorithm::Exp => "exp",
            Algorithm::Syin => "syin",
            Algorithm::Yin => "yin",
            Algorithm::SelkNs => "selk-ns",
            Algorithm::ElkNs => "elk-ns",
            Algorithm::SyinNs => "syin-ns",
            Algorithm::ExpNs => "exp-ns",
            Algorithm::NaiveSta => "naive-sta",
            Algorithm::NaiveHam => "naive-ham",
            Algorithm::NaiveElk => "naive-elk",
            Algorithm::NaiveYin => "naive-yin",
            Algorithm::Auto => "auto",
        }
    }

    /// Parse a paper-notation name.
    pub fn parse(s: &str) -> Option<Algorithm> {
        Algorithm::ALL
            .iter()
            .chain(std::iter::once(&Algorithm::Auto))
            .find(|a| a.name() == s)
            .copied()
    }

    /// The ns-variant of an sn-algorithm, if one exists.
    pub fn ns_variant(&self) -> Option<Algorithm> {
        match self {
            Algorithm::Selk => Some(Algorithm::SelkNs),
            Algorithm::Elk => Some(Algorithm::ElkNs),
            Algorithm::Syin => Some(Algorithm::SyinNs),
            Algorithm::Exp => Some(Algorithm::ExpNs),
            _ => None,
        }
    }

    /// Centroid-side requirements (same as the shard instances report).
    pub fn requirements(&self, k: usize) -> Requirements {
        // instantiate a zero-length shard and ask it
        self.make_shard(0, 0, k, crate::coordinator::groups::GroupData::group_count(k))
            .requirements()
    }

    /// Instantiate per-shard state for samples `[lo, lo+len)`.
    ///
    /// `g` is the Yinyang group count (ignored by non-group algorithms).
    /// Panics on `Auto` — the coordinator resolves it first
    /// (see `coordinator::auto::resolve`).
    pub fn make_shard(&self, lo: usize, len: usize, k: usize, g: usize) -> Box<dyn AssignStep> {
        match self {
            Algorithm::Sta => Box::new(sta::Sta::new(lo)),
            Algorithm::Selk => Box::new(selk::Selk::new(lo, len, k)),
            Algorithm::Elk => Box::new(elk::Elk::new(lo, len, k)),
            Algorithm::Ham => Box::new(ham::Ham::new(lo, len)),
            Algorithm::Ann => Box::new(ann::Ann::new(lo, len)),
            Algorithm::Exp => Box::new(exponion::Exponion::new(lo, len)),
            Algorithm::Syin => Box::new(yinyang::Yinyang::new(lo, len, g, false)),
            Algorithm::Yin => Box::new(yinyang::Yinyang::new(lo, len, g, true)),
            Algorithm::SelkNs => Box::new(ns::SelkNs::new(lo, len, k)),
            Algorithm::ElkNs => Box::new(ns::ElkNs::new(lo, len, k)),
            Algorithm::SyinNs => Box::new(ns::SyinNs::new(lo, len, g)),
            Algorithm::ExpNs => Box::new(ns::ExpNs::new(lo, len)),
            Algorithm::NaiveSta => Box::new(sta::Sta::new_naive(lo)),
            Algorithm::NaiveHam => Box::new(naive::NaiveHam::new(lo, len)),
            Algorithm::NaiveElk => Box::new(elk::Elk::new_naive(lo, len, k)),
            Algorithm::NaiveYin => Box::new(yinyang::Yinyang::new_naive(lo, len, g)),
            Algorithm::Auto => panic!("Auto must be resolved by the coordinator before sharding"),
        }
    }
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::parse(a.name()), Some(a));
        }
        assert_eq!(Algorithm::parse("auto"), Some(Algorithm::Auto));
        assert_eq!(Algorithm::parse("bogus"), None);
    }

    #[test]
    fn ns_variant_mapping() {
        assert_eq!(Algorithm::Exp.ns_variant(), Some(Algorithm::ExpNs));
        assert_eq!(Algorithm::Ham.ns_variant(), None);
    }

    #[test]
    fn shard_names_match_enum() {
        for a in Algorithm::ALL {
            let shard = a.make_shard(0, 0, 20, 2);
            assert_eq!(shard.name(), a.name());
        }
    }

    #[test]
    fn requirements_consistency() {
        // ns variants need history; exponion needs annuli + cc
        assert!(Algorithm::ExpNs.requirements(20).history);
        assert!(Algorithm::ExpNs.requirements(20).annuli);
        assert!(Algorithm::Exp.requirements(20).cc);
        assert!(Algorithm::Syin.requirements(20).groups);
        assert!(Algorithm::SyinNs.requirements(20).group_history);
        assert!(Algorithm::NaiveSta.requirements(20).full_update);
        assert!(!Algorithm::Sta.requirements(20).full_update);
    }
}
