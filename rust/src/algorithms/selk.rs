//! `selk` — Simplified Elkan (§2.2): k lower bounds `l(i,j)` and one
//! upper bound `u(i)` per sample, inner test `u(i) < l(i,j)` only —
//! a strict subset of Elkan's strategies that the paper shows is usually
//! *faster* than the fully-fledged elk.

use super::common::{batch_scan, dist_ic, AssignStep, Moved, Requirements, SharedRound};
use crate::data::source::BlockCursor;
use crate::metrics::Counters;

/// Simplified-Elkan per-sample state.
pub struct Selk {
    lo: usize,
    k: usize,
    u: Vec<f64>,
    /// `l(i,j)` row-major `len×k`.
    l: Vec<f64>,
}

impl Selk {
    /// Create for a shard `[lo, lo+len)` with `k` clusters.
    pub fn new(lo: usize, len: usize, k: usize) -> Self {
        Selk {
            lo,
            k,
            u: vec![0.0; len],
            l: vec![0.0; len * k],
        }
    }
}

impl AssignStep for Selk {
    fn name(&self) -> &'static str {
        "selk"
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn requirements(&self) -> Requirements {
        Requirements::default()
    }

    fn init(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
    ) {
        let lo = self.lo;
        let k = self.k;
        let (u, l) = (&mut self.u, &mut self.l);
        batch_scan(sh, rows, lo, lo + a.len(), ctr, |li, row| {
            let lrow = &mut l[li * k..(li + 1) * k];
            let mut best = 0usize;
            let mut bd = f64::INFINITY;
            for (j, &sq) in row.iter().enumerate() {
                let dj = sq.sqrt();
                lrow[j] = dj; // all bounds start tight
                if dj < bd {
                    bd = dj;
                    best = j;
                }
            }
            a[li] = best as u32;
            u[li] = bd;
        });
    }

    fn round(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
        moved: &mut Vec<Moved>,
    ) {
        let lo = self.lo;
        let k = self.k;
        for (li, a_li) in a.iter_mut().enumerate() {
            let gi = lo + li;
            let a0 = *a_li as usize;
            let mut ai = a0;
            // bound maintenance (eq. 4)
            self.u[li] += sh.p[ai];
            let mut u = self.u[li];
            let mut utight = false;
            let lrow = &mut self.l[li * k..(li + 1) * k];
            for (j, lj) in lrow.iter_mut().enumerate() {
                *lj -= sh.p[j];
            }
            for j in 0..k {
                if j == ai || lrow[j] >= u {
                    continue; // inner test (eq. 3)
                }
                if !utight {
                    // tighten u first — it is reused in every later test
                    ctr.assignment += 1;
                    u = crate::linalg::sqdist(rows.row(gi), sh.centroid(ai)).sqrt();
                    utight = true;
                    lrow[ai] = u; // exact distance doubles as l(i,a)
                    if lrow[j] >= u {
                        continue;
                    }
                }
                // tighten l(i,j); if still below u, j is strictly nearer
                lrow[j] = dist_ic(sh, rows, gi, j, ctr);
                if lrow[j] < u {
                    ai = j;
                    u = lrow[j]; // tight for the new assignee
                }
            }
            self.u[li] = u;
            if ai != a0 {
                moved.push(Moved {
                    i: gi as u32,
                    from: a0 as u32,
                    to: ai as u32,
                });
                *a_li = ai as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::*;

    #[test]
    fn matches_sta_on_blobs() {
        assert_exact_vs_sta(|lo, len, k, _g| Box::new(Selk::new(lo, len, k)), 400, 8, 10, 29);
    }

    #[test]
    fn matches_sta_high_dim() {
        assert_exact_vs_sta(|lo, len, k, _g| Box::new(Selk::new(lo, len, k)), 200, 40, 12, 31);
    }

    #[test]
    fn bounds_remain_valid_every_round() {
        assert_bounds_valid(
            |lo, len, k, _g| Box::new(Selk::new(lo, len, k)),
            |alg, chk| {
                let s = alg.as_any().downcast_ref::<Selk>().unwrap();
                for li in 0..chk.len() {
                    chk.upper(li, s.u[li]);
                    for j in 0..s.k {
                        chk.lower_per(li, j, s.l[li * s.k + j]);
                    }
                }
            },
        );
    }
}
