//! `sta` — the standard (unaccelerated) Lloyd assignment step (§2.1).
//!
//! Every round computes all `N×k` distances through the blocked batch
//! path and takes the arg-min. This is the baseline every bounding
//! algorithm is measured against.

use super::common::{batch_scan, scalar_scan, AssignStep, Moved, Requirements, SharedRound};
use crate::data::source::BlockCursor;
use crate::linalg::argmin;
use crate::metrics::Counters;

/// Standard algorithm state: nothing beyond the shard geometry.
pub struct Sta {
    lo: usize,
    /// Naive mode (Table 7 baseline): per-pair scalar distances instead
    /// of the blocked norm-decomposition path, and full (non-delta)
    /// centroid updates.
    naive: bool,
}

impl Sta {
    /// Create for the shard starting at global index `lo`.
    pub fn new(lo: usize) -> Self {
        Sta { lo, naive: false }
    }

    /// The deliberately unoptimised variant (Table 7 comparator).
    pub fn new_naive(lo: usize) -> Self {
        Sta { lo, naive: true }
    }

    fn scan(
        &self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        lo: usize,
        hi: usize,
        ctr: &mut crate::metrics::Counters,
        f: impl FnMut(usize, &[f64]),
    ) {
        if self.naive {
            scalar_scan(sh, rows, lo, hi, ctr, f);
        } else {
            batch_scan(sh, rows, lo, hi, ctr, f);
        }
    }
}

impl AssignStep for Sta {
    fn name(&self) -> &'static str {
        if self.naive {
            "naive-sta"
        } else {
            "sta"
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn requirements(&self) -> Requirements {
        Requirements {
            full_update: self.naive,
            ..Requirements::default()
        }
    }

    fn init(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
    ) {
        let lo = self.lo;
        self.scan(sh, rows, lo, lo + a.len(), ctr, |li, row| {
            a[li] = argmin(row).unwrap() as u32;
        });
    }

    fn round(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
        moved: &mut Vec<Moved>,
    ) {
        let lo = self.lo;
        self.scan(sh, rows, lo, lo + a.len(), ctr, |li, row| {
            let j = argmin(row).unwrap() as u32;
            if j != a[li] {
                moved.push(Moved {
                    i: (lo + li) as u32,
                    from: a[li],
                    to: j,
                });
                a[li] = j;
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::round_ctx::RoundCtxOwner;
    use crate::data::synth::blobs;

    #[test]
    fn init_assigns_nearest() {
        let ds = blobs(60, 3, 3, 0.05, 1);
        let centroids: Vec<f64> = ds.raw()[..4 * 3].to_vec();
        let owner = RoundCtxOwner::new_for_test(&ds, centroids);
        let sh = owner.shared(&ds);
        let mut a = vec![0u32; 60];
        let mut ctr = Counters::default();
        let mut cur = crate::data::DataSource::open(&ds, 0, ds.n());
        Sta::new(0).init(&sh, cur.as_mut(), &mut a, &mut ctr);
        for i in 0..60 {
            let mut bd = f64::INFINITY;
            let mut bj = 0;
            for j in 0..4 {
                let d = crate::linalg::sqdist(ds.row(i), sh.centroid(j));
                if d < bd {
                    bd = d;
                    bj = j;
                }
            }
            assert_eq!(a[i], bj as u32, "sample {i}");
        }
        assert_eq!(ctr.assignment, 60 * 4);
    }

    #[test]
    fn round_records_moves() {
        let ds = blobs(40, 2, 2, 0.05, 2);
        let centroids: Vec<f64> = ds.raw()[..2 * 2].to_vec();
        let owner = RoundCtxOwner::new_for_test(&ds, centroids);
        let sh = owner.shared(&ds);
        let mut alg = Sta::new(0);
        let mut a = vec![0u32; 40];
        let mut ctr = Counters::default();
        let mut cur = crate::data::DataSource::open(&ds, 0, ds.n());
        alg.init(&sh, cur.as_mut(), &mut a, &mut ctr);
        // re-running the round on the same centroids must move nothing
        let mut moved = Vec::new();
        alg.round(&sh, cur.as_mut(), &mut a, &mut ctr, &mut moved);
        assert!(moved.is_empty());
    }
}
