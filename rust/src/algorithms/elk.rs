//! `elk` — Elkan's algorithm (§2.3): selk plus the inter-centroid tests.
//! Keeps `cc(j,j′)` and `s(j)` to add the outer test `s(a(i))/2 > u(i)`
//! (eq. 7) and strengthen the inner test to
//! `max(l(i,j), cc(a(i),j)/2) > u(i)` (eq. 6).

use super::common::{batch_scan, dist_ic, scalar_scan, AssignStep, Moved, Requirements, SharedRound};
use crate::data::source::BlockCursor;
use crate::metrics::Counters;

/// Elkan per-sample state (same as selk; cc/s live in the round context).
pub struct Elk {
    lo: usize,
    k: usize,
    u: Vec<f64>,
    l: Vec<f64>,
    naive: bool,
}

impl Elk {
    /// Create for a shard `[lo, lo+len)` with `k` clusters.
    pub fn new(lo: usize, len: usize, k: usize) -> Self {
        Elk {
            lo,
            k,
            u: vec![0.0; len],
            l: vec![0.0; len * k],
            naive: false,
        }
    }

    /// Table 7 comparator: scalar initial scan + full centroid updates.
    pub fn new_naive(lo: usize, len: usize, k: usize) -> Self {
        Elk {
            naive: true,
            ..Elk::new(lo, len, k)
        }
    }
}

impl AssignStep for Elk {
    fn name(&self) -> &'static str {
        if self.naive {
            "naive-elk"
        } else {
            "elk"
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn requirements(&self) -> Requirements {
        Requirements {
            cc: true,
            full_update: self.naive,
            ..Requirements::default()
        }
    }

    fn init(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
    ) {
        let lo = self.lo;
        let hi = lo + a.len();
        let k = self.k;
        let naive = self.naive;
        let (u, l) = (&mut self.u, &mut self.l);
        let body = |li: usize, row: &[f64]| {
            let lrow = &mut l[li * k..(li + 1) * k];
            let mut best = 0usize;
            let mut bd = f64::INFINITY;
            for (j, &sq) in row.iter().enumerate() {
                let dj = sq.sqrt();
                lrow[j] = dj;
                if dj < bd {
                    bd = dj;
                    best = j;
                }
            }
            a[li] = best as u32;
            u[li] = bd;
        };
        if naive {
            scalar_scan(sh, rows, lo, hi, ctr, body);
        } else {
            batch_scan(sh, rows, lo, hi, ctr, body);
        }
    }

    fn round(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
        moved: &mut Vec<Moved>,
    ) {
        let lo = self.lo;
        let k = self.k;
        let cc = sh.cc.expect("elk requires cc");
        for (li, a_li) in a.iter_mut().enumerate() {
            let gi = lo + li;
            let a0 = *a_li as usize;
            let mut ai = a0;
            self.u[li] += sh.p[ai];
            let mut u = self.u[li];
            let mut utight = false;
            let lrow = &mut self.l[li * k..(li + 1) * k];
            for (j, lj) in lrow.iter_mut().enumerate() {
                *lj -= sh.p[j];
            }
            // outer test (eq. 7)
            if cc.s[ai] * 0.5 >= u {
                self.u[li] = u;
                continue;
            }
            for j in 0..k {
                if j == ai || lrow[j] >= u || cc.get(ai, j) * 0.5 >= u {
                    continue; // inner test (eq. 6)
                }
                if !utight {
                    ctr.assignment += 1;
                    u = crate::linalg::sqdist(rows.row(gi), sh.centroid(ai)).sqrt();
                    utight = true;
                    lrow[ai] = u;
                    if lrow[j] >= u || cc.get(ai, j) * 0.5 >= u {
                        continue;
                    }
                }
                lrow[j] = dist_ic(sh, rows, gi, j, ctr);
                if lrow[j] < u {
                    ai = j;
                    u = lrow[j];
                }
            }
            self.u[li] = u;
            if ai != a0 {
                moved.push(Moved {
                    i: gi as u32,
                    from: a0 as u32,
                    to: ai as u32,
                });
                *a_li = ai as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::*;

    #[test]
    fn matches_sta_on_blobs() {
        assert_exact_vs_sta(|lo, len, k, _g| Box::new(Elk::new(lo, len, k)), 400, 8, 10, 37);
    }

    #[test]
    fn matches_sta_high_dim() {
        assert_exact_vs_sta(|lo, len, k, _g| Box::new(Elk::new(lo, len, k)), 200, 32, 15, 41);
    }

    #[test]
    fn bounds_remain_valid_every_round() {
        assert_bounds_valid(
            |lo, len, k, _g| Box::new(Elk::new(lo, len, k)),
            |alg, chk| {
                let e = alg.as_any().downcast_ref::<Elk>().unwrap();
                for li in 0..chk.len() {
                    chk.upper(li, e.u[li]);
                    for j in 0..e.k {
                        chk.lower_per(li, j, e.l[li * e.k + j]);
                    }
                }
            },
        );
    }
}
