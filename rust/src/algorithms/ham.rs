//! `ham` — Hamerly's algorithm (§2.4): one upper bound `u(i)` on the
//! assigned centroid, one lower bound `l(i)` on *all* other centroids,
//! and the outer test `max(l(i), s(a(i))/2) ≥ u(i)`.

use super::common::{batch_scan, dist_ic, top2_sqrt, AssignStep, Moved, Requirements, SharedRound};
use crate::data::source::BlockCursor;
use crate::linalg::Top2;
use crate::metrics::Counters;

/// Hamerly per-sample state.
pub struct Ham {
    lo: usize,
    /// Upper bound on the distance to the assigned centroid.
    u: Vec<f64>,
    /// Lower bound on the distance to every other centroid.
    l: Vec<f64>,
}

impl Ham {
    /// Create for a shard `[lo, lo+len)`.
    pub fn new(lo: usize, len: usize) -> Self {
        Ham {
            lo,
            u: vec![0.0; len],
            l: vec![0.0; len],
        }
    }

    /// Bound update at round start; returns the loose-bound gate value
    /// `max(l(i), s(a)/2)`.
    #[inline]
    fn update_bounds(&mut self, sh: &SharedRound, li: usize, ai: usize) -> f64 {
        self.u[li] += sh.p[ai];
        self.l[li] -= if sh.p_argmax == ai {
            sh.p_max2
        } else {
            sh.p_max
        };
        self.l[li].max(sh.s(ai) * 0.5)
    }
}

impl AssignStep for Ham {
    fn name(&self) -> &'static str {
        "ham"
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn requirements(&self) -> Requirements {
        Requirements {
            cc: true, // for s(j)
            ..Requirements::default()
        }
    }

    fn init(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
    ) {
        let lo = self.lo;
        let (u, l) = (&mut self.u, &mut self.l);
        batch_scan(sh, rows, lo, lo + a.len(), ctr, |li, row| {
            let t2 = top2_sqrt(row);
            a[li] = t2.idx1 as u32;
            u[li] = t2.val1;
            l[li] = t2.val2;
        });
    }

    fn round(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
        moved: &mut Vec<Moved>,
    ) {
        let lo = self.lo;
        for (li, a_li) in a.iter_mut().enumerate() {
            let ai = *a_li as usize;
            let gi = lo + li;
            let m = self.update_bounds(sh, li, ai);
            if m >= self.u[li] {
                continue; // outer test with loose u
            }
            // tighten u and retry
            self.u[li] = dist_ic(sh, rows, gi, ai, ctr);
            if m >= self.u[li] {
                continue;
            }
            // full scan reveals n1 and n2
            let mut t2 = Top2::new();
            for j in 0..sh.k {
                let dj = if j == ai {
                    self.u[li]
                } else {
                    dist_ic(sh, rows, gi, j, ctr)
                };
                t2.push(j, dj);
            }
            self.u[li] = t2.val1;
            self.l[li] = t2.val2;
            if t2.idx1 != ai {
                moved.push(Moved {
                    i: gi as u32,
                    from: ai as u32,
                    to: t2.idx1 as u32,
                });
                *a_li = t2.idx1 as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::*;

    #[test]
    fn matches_sta_on_blobs() {
        assert_exact_vs_sta(|lo, len, _k, _g| Box::new(Ham::new(lo, len)), 400, 6, 8, 11);
    }

    #[test]
    fn bounds_remain_valid_every_round() {
        assert_bounds_valid(
            |lo, len, _k, _g| Box::new(Ham::new(lo, len)),
            |alg, chk| {
                let ham = alg.as_any().downcast_ref::<Ham>().unwrap();
                for li in 0..chk.len() {
                    chk.upper(li, ham.u[li]);
                    chk.lower_all(li, ham.l[li]);
                }
            },
        );
    }
}
