//! `syin` / `yin` — Simplified Yinyang and Yinyang (§2.6, Ding et al.
//! 2015): per-*group* lower bounds `l(i,f)` as a compromise between elk's
//! k bounds and ham's single bound. `yin` adds the SM-C.1 local filter
//! inside group scans; `syin` (this paper's simplification) drops it —
//! and is usually faster.

use super::common::{batch_scan, dist_ic, scalar_scan, AssignStep, Moved, Requirements, SharedRound};
use crate::data::source::BlockCursor;
use crate::linalg::Top2;
use crate::metrics::Counters;

/// Yinyang-family per-sample state; `filter` selects yin vs syin.
pub struct Yinyang {
    lo: usize,
    g: usize,
    /// Upper bound on distance to assigned centroid.
    u: Vec<f64>,
    /// Group lower bounds, row-major `len×g`.
    l: Vec<f64>,
    /// yin's local filter enabled?
    filter: bool,
    naive: bool,
    // per-sample scratch (allocated once)
    gmin: Vec<Top2>,
    skipmin: Vec<f64>,
    scanned: Vec<bool>,
}

impl Yinyang {
    /// `filter=false` → syin, `filter=true` → yin.
    pub fn new(lo: usize, len: usize, g: usize, filter: bool) -> Self {
        Yinyang {
            lo,
            g,
            u: vec![0.0; len],
            l: vec![0.0; len * g],
            filter,
            naive: false,
            gmin: vec![Top2::new(); g],
            skipmin: vec![f64::INFINITY; g],
            scanned: vec![false; g],
        }
    }

    /// Table 7 comparator: yin with scalar initial scan + full updates.
    pub fn new_naive(lo: usize, len: usize, g: usize) -> Self {
        Yinyang {
            naive: true,
            ..Yinyang::new(lo, len, g, true)
        }
    }
}

impl AssignStep for Yinyang {
    fn name(&self) -> &'static str {
        match (self.naive, self.filter) {
            (true, _) => "naive-yin",
            (false, true) => "yin",
            (false, false) => "syin",
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn requirements(&self) -> Requirements {
        Requirements {
            groups: true,
            full_update: self.naive,
            ..Requirements::default()
        }
    }

    fn init(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
    ) {
        let lo = self.lo;
        let hi = lo + a.len();
        let g = self.g;
        let naive = self.naive;
        let gd = sh.groups.expect("yinyang requires groups");
        let (u, l) = (&mut self.u, &mut self.l);
        let mut gms = vec![Top2::new(); g];
        let body = |li: usize, row: &[f64]| {
            for gm in gms.iter_mut() {
                *gm = Top2::new();
            }
            let mut best = Top2::new();
            for (j, &sq) in row.iter().enumerate() {
                let dj = sq.sqrt();
                let f = gd.group_of[j] as usize;
                gms[f].push(j, dj);
                best.push(j, dj);
            }
            let ai = best.idx1;
            a[li] = ai as u32;
            u[li] = best.val1;
            let lrow = &mut l[li * g..(li + 1) * g];
            for (f, gm) in gms.iter().enumerate() {
                lrow[f] = if gm.idx1 == ai { gm.val2 } else { gm.val1 };
            }
        };
        if naive {
            scalar_scan(sh, rows, lo, hi, ctr, body);
        } else {
            batch_scan(sh, rows, lo, hi, ctr, body);
        }
    }

    fn round(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
        moved: &mut Vec<Moved>,
    ) {
        let lo = self.lo;
        let g = self.g;
        let gd = sh.groups.expect("yinyang requires groups");
        for (li, a_li) in a.iter_mut().enumerate() {
            let gi = lo + li;
            let a0 = *a_li as usize;
            // bound maintenance
            self.u[li] += sh.p[a0];
            let lrow = &mut self.l[li * g..(li + 1) * g];
            let mut minl = f64::INFINITY;
            for (f, lf) in lrow.iter_mut().enumerate() {
                *lf -= gd.q[f];
                if *lf < minl {
                    minl = *lf;
                }
            }
            // outer test (eq. 10)
            if minl >= self.u[li] {
                continue;
            }
            let d_old = dist_ic(sh, rows, gi, a0, ctr); // tighten u
            self.u[li] = d_old;
            if minl >= d_old {
                continue;
            }
            let f_old = gd.group_of[a0] as usize;
            let mut best = Top2::new();
            best.push(a0, d_old);
            for f in 0..g {
                // group test (eq. 11) against the running best distance —
                // it can only shrink, making the test stricter (still exact)
                let el = lrow[f];
                let scan = el < best.val1;
                self.scanned[f] = scan;
                if !scan {
                    continue;
                }
                let lprev = el + gd.q[f]; // last round's value, for the local filter
                let mut gm = Top2::new();
                if f == f_old {
                    gm.push(a0, d_old);
                }
                let mut skip_min = f64::INFINITY;
                for &j in &gd.members[f] {
                    let j = j as usize;
                    if j == a0 {
                        continue;
                    }
                    if self.filter {
                        // yin's local test (SM-C.1): per-centroid bound
                        // lprev − p(j) ≥ running second-best ⇒ j cannot
                        // enter the top-2, skip its distance
                        let lb = lprev - sh.p[j];
                        if lb >= best.val2 {
                            if lb < skip_min {
                                skip_min = lb;
                            }
                            continue;
                        }
                    }
                    let dj = dist_ic(sh, rows, gi, j, ctr);
                    gm.push(j, dj);
                    best.push(j, dj);
                }
                self.gmin[f] = gm;
                self.skipmin[f] = skip_min;
            }
            let a_new = best.idx1;
            self.u[li] = best.val1;
            for f in 0..g {
                if self.scanned[f] {
                    let gm = &self.gmin[f];
                    let base = if gm.idx1 == a_new { gm.val2 } else { gm.val1 };
                    lrow[f] = base.min(self.skipmin[f]);
                } else if f == f_old && a_new != a0 {
                    // old centroid joins this group's bound set; its exact
                    // distance is known
                    lrow[f] = lrow[f].min(d_old);
                }
            }
            if a_new != a0 {
                moved.push(Moved {
                    i: gi as u32,
                    from: a0 as u32,
                    to: a_new as u32,
                });
                *a_li = a_new as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::*;

    #[test]
    fn syin_matches_sta() {
        assert_exact_vs_sta(
            |lo, len, _k, g| Box::new(Yinyang::new(lo, len, g, false)),
            500,
            10,
            20,
            43,
        );
    }

    #[test]
    fn yin_matches_sta() {
        assert_exact_vs_sta(
            |lo, len, _k, g| Box::new(Yinyang::new(lo, len, g, true)),
            500,
            10,
            20,
            47,
        );
    }

    #[test]
    fn syin_matches_sta_many_clusters() {
        assert_exact_vs_sta(
            |lo, len, _k, g| Box::new(Yinyang::new(lo, len, g, false)),
            600,
            6,
            40,
            53,
        );
    }

    #[test]
    fn yin_matches_sta_many_clusters() {
        assert_exact_vs_sta(
            |lo, len, _k, g| Box::new(Yinyang::new(lo, len, g, true)),
            600,
            6,
            40,
            59,
        );
    }

    #[test]
    fn syin_group_bounds_valid() {
        assert_bounds_valid(
            |lo, len, _k, g| Box::new(Yinyang::new(lo, len, g, false)),
            |alg, chk| {
                let y = alg.as_any().downcast_ref::<Yinyang>().unwrap();
                for li in 0..chk.len() {
                    chk.upper(li, y.u[li]);
                    for f in 0..y.g {
                        chk.lower_group(li, f, y.l[li * y.g + f]);
                    }
                }
            },
        );
    }

    #[test]
    fn yin_group_bounds_valid() {
        assert_bounds_valid(
            |lo, len, _k, g| Box::new(Yinyang::new(lo, len, g, true)),
            |alg, chk| {
                let y = alg.as_any().downcast_ref::<Yinyang>().unwrap();
                for li in 0..chk.len() {
                    chk.upper(li, y.u[li]);
                    for f in 0..y.g {
                        chk.lower_group(li, f, y.l[li * y.g + f]);
                    }
                }
            },
        );
    }
}
