//! Shared infrastructure for all assignment-step algorithms.
//!
//! Each algorithm is a struct owning *per-sample* state for one shard of
//! the data (a contiguous range of sample indices). The coordinator owns
//! everything centroid-side and rebuilds it once per round
//! ([`SharedRound`]); shards then run (possibly in parallel) without any
//! synchronisation, which is exactly the parallelisation the paper uses
//! (§4.2: samples are processed independently).
//!
//! Sample values are reached through the block-lease contract: every
//! worker opens a [`BlockCursor`] for its shard and the algorithm reads
//! rows from that cursor — never from the source directly. That is what
//! lets an out-of-core source serve the scan from a per-worker resident
//! window (see [`data::source`](crate::data::source)).

use crate::coordinator::annuli::Annuli;
use crate::coordinator::ccdist::CcData;
use crate::coordinator::groups::GroupData;
use crate::coordinator::history::HistoryRound;
use crate::coordinator::sorted_norms::SortedNorms;
use crate::data::source::BlockCursor;
use crate::data::DataSource;
use crate::linalg::{sqdist_argmin_block, sqdist_batch_block, Top2};
use crate::metrics::Counters;
use crate::runtime::pool::{SharedSliceMut, WorkerPool};

/// What centroid-side structures an algorithm needs per round.
/// The coordinator builds only what is requested (building e.g. the
/// inter-centroid matrix costs k(k−1)/2 distance calculations per round,
/// which the paper's `q_au` accounting must reflect).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Requirements {
    /// Inter-centroid distance matrix `cc(j,j′)` + `s(j)` (elk, ham, ann, exp).
    pub cc: bool,
    /// Centroid norms sorted per round (ann).
    pub sorted_norms: bool,
    /// Exponion's concentric-annuli partial sort (exp).
    pub annuli: bool,
    /// Yinyang cluster grouping + per-round `q(f)` (syin, yin).
    pub groups: bool,
    /// ns-bound centroid history (all `-ns` variants).
    pub history: bool,
    /// ns history must also carry per-group displacement maxima (syin-ns).
    pub group_history: bool,
    /// Disable the delta ("changed samples only") centroid update —
    /// used by the deliberately naive Table 7 baselines.
    pub full_update: bool,
}

/// One sample moved cluster during a round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Moved {
    /// Global sample index.
    pub i: u32,
    /// Previous cluster.
    pub from: u32,
    /// New cluster.
    pub to: u32,
}

/// Read-only, centroid-side context for one assignment round.
///
/// Built once per round by the coordinator and shared by every worker.
/// Sample *values* are not reachable through it — each worker reads its
/// shard through its own [`BlockCursor`]; `data` is kept for shape
/// queries (`n`, `d`) only.
pub struct SharedRound<'a> {
    /// The sample source, for shape queries and cursor opening. Row
    /// access goes through the per-worker cursor passed to
    /// [`AssignStep::init`] / [`AssignStep::round`].
    pub data: &'a dyn DataSource,
    /// Number of clusters.
    pub k: usize,
    /// Round index: 0 is the initial full assignment.
    pub round: usize,
    /// Current centroids, row-major `k×d`.
    pub centroids: &'a [f64],
    /// `‖c(j)‖²`, refreshed each round (paper §4.1.1).
    pub cnorms: &'a [f64],
    /// `p(j)`: distance moved by each centroid in the last update step.
    pub p: &'a [f64],
    /// `max_j p(j)` and where it occurs, plus the runner-up — lets ham
    /// subtract the max over `j ≠ a(i)` in O(1).
    pub p_max: f64,
    /// Second-largest displacement.
    pub p_max2: f64,
    /// Index attaining `p_max`.
    pub p_argmax: usize,
    /// Inter-centroid data, if requested.
    pub cc: Option<&'a CcData>,
    /// Sorted centroid norms, if requested.
    pub sorted_norms: Option<&'a SortedNorms>,
    /// Exponion annuli, if requested.
    pub annuli: Option<&'a Annuli>,
    /// Yinyang groups, if requested.
    pub groups: Option<&'a GroupData>,
    /// ns-bound history, if requested.
    pub history: Option<&'a HistoryRound>,
}

impl<'a> SharedRound<'a> {
    /// Centroid `j` as a row slice.
    #[inline]
    pub fn centroid(&self, j: usize) -> &'a [f64] {
        let d = self.data.d();
        &self.centroids[j * d..(j + 1) * d]
    }

    /// `s(j)`: distance from centroid j to its nearest other centroid.
    #[inline]
    pub fn s(&self, j: usize) -> f64 {
        self.cc.expect("cc not built").s[j]
    }
}

/// The assignment-step interface every algorithm implements for a shard
/// of samples `[lo, hi)`.
///
/// `rows` is the worker's block cursor for the shard — the only route to
/// sample values. `a` is the shard's slice of the global assignment
/// array (local index 0 is global `lo`). Implementations must append
/// every assignment change to `moved` with *global* indices.
pub trait AssignStep: Send {
    /// Paper-notation name ("exp-ns", "selk", …).
    fn name(&self) -> &'static str;

    /// Downcast hook so tests can inspect per-sample bound state.
    fn as_any(&self) -> &dyn std::any::Any;

    /// Centroid-side structures this algorithm needs.
    fn requirements(&self) -> Requirements;

    /// Initial full assignment (round 0): set `a`, make all bounds tight.
    fn init(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
    );

    /// One assignment round (round ≥ 1).
    fn round(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
        moved: &mut Vec<Moved>,
    );
}

/// Block size for the batched scans — also the lease size, so a
/// windowed cursor never needs a window smaller than this.
pub(crate) const INIT_BLOCK: usize = 128;

/// Blocked squared-distance scan of rows `[lo, hi)` leased from `cur`
/// against `centroids` (`cnorms.len()` of them): calls `f(i − lo, row)`
/// with each sample's full `k`-vector of squared distances. Counter-free
/// — the one shared kernel under both the fit path ([`batch_scan`]) and
/// the serving path
/// ([`FittedModel::predict`](crate::model::FittedModel::predict)), so
/// their outputs are bit-identical by construction. Each per-row result
/// depends only on that row's values, so lease/block boundaries never
/// affect the output bits.
pub fn blocked_scan(
    cur: &mut dyn BlockCursor,
    centroids: &[f64],
    cnorms: &[f64],
    lo: usize,
    hi: usize,
    mut f: impl FnMut(usize, &[f64]),
) {
    let d = cur.d();
    let k = cnorms.len();
    let mut buf = vec![0.0; INIT_BLOCK * k];
    let mut start = lo;
    while start < hi {
        let m = INIT_BLOCK.min(hi - start);
        let block = cur.lease(start, m);
        sqdist_batch_block(
            block.rows(),
            block.sqnorms(),
            centroids,
            cnorms,
            d,
            &mut buf[..m * k],
        );
        for (i, row) in buf[..m * k].chunks_exact(k).enumerate() {
            f(start - lo + i, row);
        }
        start += m;
    }
}

/// Fused labels+distances scan of rows `[lo, hi)` leased from `cur`:
/// for each sample, the nearest centroid's index (first-lowest-index
/// ties) and squared distance, written at `labels[i − lo]` /
/// `dists_sq[i − lo]`. The blocked counterpart of [`blocked_scan`] for
/// the label-only case — it runs
/// [`sqdist_argmin_block`] per lease, so the `m×k` distance matrix is
/// never materialised, and is bit-identical to `blocked_scan` +
/// per-row argmin (the fused kernel shares the same panel micro-kernel
/// and transform).
pub fn blocked_argmin_scan(
    cur: &mut dyn BlockCursor,
    centroids: &[f64],
    cnorms: &[f64],
    lo: usize,
    hi: usize,
    labels: &mut [u32],
    dists_sq: &mut [f64],
) {
    assert_eq!(labels.len(), hi - lo);
    assert_eq!(dists_sq.len(), hi - lo);
    let d = cur.d();
    let mut start = lo;
    while start < hi {
        let m = INIT_BLOCK.min(hi - start);
        let block = cur.lease(start, m);
        let off = start - lo;
        sqdist_argmin_block(
            block.rows(),
            block.sqnorms(),
            centroids,
            cnorms,
            d,
            &mut labels[off..off + m],
            &mut dists_sq[off..off + m],
        );
        start += m;
    }
}

/// Pool-sharded nearest-centroid labelling: writes every row of
/// `data`'s label (first-lowest-index tie-breaking) into `labels`.
///
/// Chunks are claimed dynamically but their *geometry* is a function of
/// `n` alone ([`sched::label_chunk`](crate::coordinator::sched::label_chunk)),
/// and each element's math is independent of the partition, so both the
/// output and the per-chunk cursor behaviour are **identical at any
/// pool width**. This is the one serving/labelling kernel —
/// [`FittedModel::predict`](crate::model::FittedModel::predict) and the
/// mini-batch driver's final full-data pass both call it, so their
/// outputs agree by construction. Each chunk opens its own cursor, so
/// out-of-core sources serve the scan from per-worker windows.
pub fn nearest_labels(
    pool: &WorkerPool,
    data: &dyn DataSource,
    centroids: &[f64],
    cnorms: &[f64],
    labels: &mut [u32],
) {
    // hard assert: the chunked writes below are unchecked in release,
    // so a short buffer must fail here, not corrupt the heap
    assert_eq!(labels.len(), data.n(), "labels buffer must hold one label per row");
    let n = data.n();
    let cells = SharedSliceMut::new(labels);
    pool.for_each_chunk_exact(n, crate::coordinator::sched::label_chunk(n), |lo, hi| {
        // chunks are disjoint sample ranges; element-wise writes only
        let out = unsafe { cells.range(lo, hi) };
        let mut cur = data.open(lo, hi - lo);
        let mut dists = vec![0.0; hi - lo];
        blocked_argmin_scan(cur.as_mut(), centroids, cnorms, lo, hi, out, &mut dists);
    });
}

/// Batched full distance scan over the shard `[lo, hi)`: calls
/// `f(local_i, row)` with the full `k`-vector of squared distances for
/// each sample. Used by every algorithm's `init`. Counts `(hi−lo)·k`
/// assignment distances.
pub fn batch_scan(
    sh: &SharedRound,
    rows: &mut dyn BlockCursor,
    lo: usize,
    hi: usize,
    ctr: &mut Counters,
    f: impl FnMut(usize, &[f64]),
) {
    blocked_scan(rows, sh.centroids, sh.cnorms, lo, hi, f);
    ctr.assignment += ((hi - lo) * sh.k) as u64;
}

/// Unblocked, per-pair full distance scan — the *naive* counterpart of
/// [`batch_scan`], used by the Table 7 baseline family to quantify what
/// the paper's §4.1.1 engineering (norm decomposition + blocked products)
/// is worth. Same contract as `batch_scan` (rows leased one at a time).
pub fn scalar_scan(
    sh: &SharedRound,
    rows: &mut dyn BlockCursor,
    lo: usize,
    hi: usize,
    ctr: &mut Counters,
    mut f: impl FnMut(usize, &[f64]),
) {
    let k = sh.k;
    let mut row = vec![0.0; k];
    for gi in lo..hi {
        let x = rows.row(gi);
        for (j, slot) in row.iter_mut().enumerate() {
            *slot = crate::linalg::sqdist(x, sh.centroid(j));
        }
        f(gi - lo, &row);
    }
    ctr.assignment += ((hi - lo) * k) as u64;
}

/// Top-2 of a squared-distance row, converting to *plain* distances
/// (every bound in the paper is on plain Euclidean distance).
#[inline]
pub fn top2_sqrt(row: &[f64]) -> Top2 {
    let mut t = Top2::new();
    for (j, &sq) in row.iter().enumerate() {
        t.push(j, sq.sqrt());
    }
    t
}

/// Plain (non-squared) distance from sample `i` (leased from `rows`) to
/// centroid `j`, counting one assignment distance.
#[inline]
pub fn dist_ic(
    sh: &SharedRound,
    rows: &mut dyn BlockCursor,
    i: usize,
    j: usize,
    ctr: &mut Counters,
) -> f64 {
    ctr.assignment += 1;
    crate::linalg::sqdist(rows.row(i), sh.centroid(j)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::round_ctx::RoundCtxOwner;
    use crate::data::synth::blobs;

    #[test]
    fn batch_scan_matches_direct() {
        let ds = blobs(97, 6, 4, 0.2, 3);
        let k = 5;
        let centroids: Vec<f64> = ds.raw()[..k * 6].to_vec();
        let owner = RoundCtxOwner::new_for_test(&ds, centroids);
        let sh = owner.shared(&ds);
        let mut ctr = Counters::default();
        let mut rows = Vec::new();
        let mut cur = ds.open(0, ds.n());
        batch_scan(&sh, cur.as_mut(), 10, 40, &mut ctr, |li, row| {
            rows.push((li, row.to_vec()))
        });
        assert_eq!(rows.len(), 30);
        assert_eq!(ctr.assignment, 30 * k as u64);
        for (li, row) in &rows {
            let gi = 10 + li;
            for j in 0..k {
                let direct = crate::linalg::sqdist(ds.row(gi), sh.centroid(j));
                assert!((row[j] - direct).abs() < 1e-9, "i={gi} j={j}");
            }
        }
    }

    #[test]
    fn scalar_scan_matches_batch_scan() {
        let ds = blobs(61, 3, 3, 0.2, 5);
        let k = 4;
        let centroids: Vec<f64> = ds.raw()[..k * 3].to_vec();
        let owner = RoundCtxOwner::new_for_test(&ds, centroids);
        let sh = owner.shared(&ds);
        let mut ctr = Counters::default();
        let mut batch = Vec::new();
        let mut cur = ds.open(0, ds.n());
        batch_scan(&sh, cur.as_mut(), 0, 61, &mut ctr, |li, row| {
            batch.push((li, row.to_vec()))
        });
        let mut scalar = Vec::new();
        let mut cur = ds.open(0, ds.n());
        scalar_scan(&sh, cur.as_mut(), 0, 61, &mut ctr, |li, row| {
            scalar.push((li, row.to_vec()))
        });
        for ((li, b), (lj, s)) in batch.iter().zip(&scalar) {
            assert_eq!(li, lj);
            for (x, y) in b.iter().zip(s) {
                assert!((x - y).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn fused_argmin_scan_bit_identical_to_blocked_scan_plus_argmin() {
        let ds = blobs(397, 6, 4, 0.2, 9); // not a multiple of INIT_BLOCK
        let k = 67; // straddles the gemm panel width
        let centroids: Vec<f64> = ds.raw()[..k * 6].to_vec();
        let cnorms = crate::linalg::sqnorms_rows(&centroids, 6);
        let (lo, hi) = (3, 397);
        let mut want_labels = vec![0u32; hi - lo];
        let mut want_dists = vec![0.0; hi - lo];
        let mut cur = ds.open(lo, hi - lo);
        blocked_scan(cur.as_mut(), &centroids, &cnorms, lo, hi, |i, row| {
            let j = crate::linalg::argmin(row).unwrap();
            want_labels[i] = j as u32;
            want_dists[i] = row[j];
        });
        let mut labels = vec![u32::MAX; hi - lo];
        let mut dists = vec![0.0; hi - lo];
        let mut cur = ds.open(lo, hi - lo);
        blocked_argmin_scan(cur.as_mut(), &centroids, &cnorms, lo, hi, &mut labels, &mut dists);
        assert_eq!(labels, want_labels);
        for (a, b) in dists.iter().zip(&want_dists) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn top2_sqrt_orders_plain_distances() {
        let t = top2_sqrt(&[9.0, 1.0, 4.0]);
        assert_eq!(t.idx1, 1);
        assert!((t.val1 - 1.0).abs() < 1e-12);
        assert_eq!(t.idx2, 2);
        assert!((t.val2 - 2.0).abs() < 1e-12);
    }
}
