//! `ann` — Drake's Annular algorithm (§2.5): ham plus an origin-centred
//! annulus filter. When ham's scan is unavoidable, only centroids whose
//! norm lies within `R(i)` of `‖x(i)‖` need to be considered, where
//! `R(i) = max(u(i), ‖x(i) − c(b(i))‖)` and `b(i)` tracks the
//! second-nearest centroid the way `a(i)` tracks the nearest.

use super::common::{batch_scan, dist_ic, top2_sqrt, AssignStep, Moved, Requirements, SharedRound};
use crate::data::source::BlockCursor;
use crate::linalg::Top2;
use crate::metrics::Counters;

/// Annular per-sample state: ham's bounds plus `b(i)`.
pub struct Ann {
    lo: usize,
    u: Vec<f64>,
    l: Vec<f64>,
    /// Stale index of the (approximately) second-nearest centroid.
    b: Vec<u32>,
}

impl Ann {
    /// Create for a shard `[lo, lo+len)`.
    pub fn new(lo: usize, len: usize) -> Self {
        Ann {
            lo,
            u: vec![0.0; len],
            l: vec![0.0; len],
            b: vec![0; len],
        }
    }
}

impl AssignStep for Ann {
    fn name(&self) -> &'static str {
        "ann"
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn requirements(&self) -> Requirements {
        Requirements {
            cc: true,
            sorted_norms: true,
            ..Requirements::default()
        }
    }

    fn init(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
    ) {
        let lo = self.lo;
        let (u, l, b) = (&mut self.u, &mut self.l, &mut self.b);
        batch_scan(sh, rows, lo, lo + a.len(), ctr, |li, row| {
            let t2 = top2_sqrt(row);
            a[li] = t2.idx1 as u32;
            u[li] = t2.val1;
            l[li] = t2.val2;
            b[li] = t2.idx2 as u32;
        });
    }

    fn round(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
        moved: &mut Vec<Moved>,
    ) {
        let lo = self.lo;
        let norms = sh.sorted_norms.expect("ann requires sorted norms");
        for (li, a_li) in a.iter_mut().enumerate() {
            let ai = *a_li as usize;
            let gi = lo + li;
            // ham's bound update + outer test
            self.u[li] += sh.p[ai];
            self.l[li] -= if sh.p_argmax == ai {
                sh.p_max2
            } else {
                sh.p_max
            };
            let m = self.l[li].max(sh.s(ai) * 0.5);
            if m >= self.u[li] {
                continue;
            }
            self.u[li] = dist_ic(sh, rows, gi, ai, ctr);
            if m >= self.u[li] {
                continue;
            }
            // annular scan: R = max(u, ‖x − c(b)‖), filter on norms (eq. 9)
            let bi = self.b[li] as usize;
            let dxb = dist_ic(sh, rows, gi, bi, ctr);
            let r = self.u[li].max(dxb);
            let xnorm = rows.sqnorm(gi).sqrt();
            let mut t2 = Top2::new();
            for j in norms.window(xnorm, r) {
                let j = j as usize;
                let dj = if j == ai {
                    self.u[li]
                } else if j == bi {
                    dxb
                } else {
                    dist_ic(sh, rows, gi, j, ctr)
                };
                t2.push(j, dj);
            }
            // a(i), b(i) ∈ J(i) by construction, so t2 saw ≥ 2 entries
            self.u[li] = t2.val1;
            self.l[li] = t2.val2;
            self.b[li] = t2.idx2 as u32;
            if t2.idx1 != ai {
                moved.push(Moved {
                    i: gi as u32,
                    from: ai as u32,
                    to: t2.idx1 as u32,
                });
                *a_li = t2.idx1 as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::*;

    #[test]
    fn matches_sta_on_blobs() {
        assert_exact_vs_sta(|lo, len, _k, _g| Box::new(Ann::new(lo, len)), 400, 4, 10, 13);
    }

    #[test]
    fn matches_sta_low_dim() {
        assert_exact_vs_sta(|lo, len, _k, _g| Box::new(Ann::new(lo, len)), 600, 2, 16, 17);
    }

    #[test]
    fn bounds_remain_valid_every_round() {
        assert_bounds_valid(
            |lo, len, _k, _g| Box::new(Ann::new(lo, len)),
            |alg, chk| {
                let ann = alg.as_any().downcast_ref::<Ann>().unwrap();
                for li in 0..chk.len() {
                    chk.upper(li, ann.u[li]);
                    chk.lower_all(li, ann.l[li]);
                    chk.b_differs(li, ann.b[li]);
                }
            },
        );
    }
}
