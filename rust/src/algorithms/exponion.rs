//! `exp` — the **Exponion** algorithm, this paper's §3.1 contribution.
//!
//! Like ann it extends ham with a candidate filter on the unavoidable
//! scan, but the filter is a *ball centred on the assigned centroid*
//! `B(c(a(i)), 2u(i) + s(a(i)))` rather than an origin-centred annulus:
//! in `R^d` the volume ratio favours the ball by `d·(w/r)^{d−1}`.
//! Candidates come from the coordinator's concentric-annuli partial sort
//! of the inter-centroid matrix ([`crate::coordinator::annuli::Annuli`]),
//! which over-covers by at most 2× (paper: `|J*(i)| ≤ 2|J(i)|`).

use super::common::{batch_scan, dist_ic, top2_sqrt, AssignStep, Moved, Requirements, SharedRound};
use crate::data::source::BlockCursor;
use crate::linalg::Top2;
use crate::metrics::Counters;

/// Exponion per-sample state — identical to ham's (no `b(i)` needed).
pub struct Exponion {
    lo: usize,
    u: Vec<f64>,
    l: Vec<f64>,
}

impl Exponion {
    /// Create for a shard `[lo, lo+len)`.
    pub fn new(lo: usize, len: usize) -> Self {
        Exponion {
            lo,
            u: vec![0.0; len],
            l: vec![0.0; len],
        }
    }
}

impl AssignStep for Exponion {
    fn name(&self) -> &'static str {
        "exp"
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn requirements(&self) -> Requirements {
        Requirements {
            cc: true,
            annuli: true,
            ..Requirements::default()
        }
    }

    fn init(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
    ) {
        let lo = self.lo;
        let (u, l) = (&mut self.u, &mut self.l);
        batch_scan(sh, rows, lo, lo + a.len(), ctr, |li, row| {
            let t2 = top2_sqrt(row);
            a[li] = t2.idx1 as u32;
            u[li] = t2.val1;
            l[li] = t2.val2;
        });
    }

    fn round(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
        moved: &mut Vec<Moved>,
    ) {
        let lo = self.lo;
        let annuli = sh.annuli.expect("exp requires annuli");
        for (li, a_li) in a.iter_mut().enumerate() {
            let ai = *a_li as usize;
            let gi = lo + li;
            // ham's bound update + outer test
            self.u[li] += sh.p[ai];
            self.l[li] -= if sh.p_argmax == ai {
                sh.p_max2
            } else {
                sh.p_max
            };
            let m = self.l[li].max(sh.s(ai) * 0.5);
            if m >= self.u[li] {
                continue;
            }
            self.u[li] = dist_ic(sh, rows, gi, ai, ctr);
            if m >= self.u[li] {
                continue;
            }
            // exponion scan: ball of radius 2u + s(a) around c(a) (eq. 12)
            let r = 2.0 * self.u[li] + sh.s(ai);
            let mut t2 = Top2::new();
            t2.push(ai, self.u[li]);
            for &j in annuli.candidates(ai, r) {
                t2.push(j as usize, dist_ic(sh, rows, gi, j as usize, ctr));
            }
            self.u[li] = t2.val1;
            self.l[li] = t2.val2;
            if t2.idx1 != ai {
                moved.push(Moved {
                    i: gi as u32,
                    from: ai as u32,
                    to: t2.idx1 as u32,
                });
                *a_li = t2.idx1 as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::*;

    #[test]
    fn matches_sta_on_blobs() {
        assert_exact_vs_sta(
            |lo, len, _k, _g| Box::new(Exponion::new(lo, len)),
            400,
            4,
            10,
            19,
        );
    }

    #[test]
    fn matches_sta_low_dim_many_clusters() {
        assert_exact_vs_sta(
            |lo, len, _k, _g| Box::new(Exponion::new(lo, len)),
            800,
            2,
            32,
            23,
        );
    }

    #[test]
    fn bounds_remain_valid_every_round() {
        assert_bounds_valid(
            |lo, len, _k, _g| Box::new(Exponion::new(lo, len)),
            |alg, chk| {
                let e = alg.as_any().downcast_ref::<Exponion>().unwrap();
                for li in 0..chk.len() {
                    chk.upper(li, e.u[li]);
                    chk.lower_all(li, e.l[li]);
                }
            },
        );
    }
}
