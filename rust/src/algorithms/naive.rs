//! `naive-ham` — a deliberately less-engineered Hamerly used by the
//! Table 7 implementation comparison. Algorithmically identical to
//! [`Ham`](super::ham::Ham) (same tests, same distance counts up to the
//! scan path) but missing the §4.1.1 engineering:
//!
//! * initial scan is per-pair scalar, not the blocked norm-decomposition;
//! * the "max displacement over j ≠ a(i)" is found with a per-sample O(k)
//!   scan of `p` instead of the O(1) max/argmax/second-max trick;
//! * centroid updates are recomputed from scratch (`full_update`).

use super::common::{
    dist_ic, scalar_scan, top2_sqrt, AssignStep, Moved, Requirements, SharedRound,
};
use crate::data::source::BlockCursor;
use crate::linalg::Top2;
use crate::metrics::Counters;

/// Naive-Hamerly per-sample state.
pub struct NaiveHam {
    lo: usize,
    u: Vec<f64>,
    l: Vec<f64>,
}

impl NaiveHam {
    /// Create for a shard `[lo, lo+len)`.
    pub fn new(lo: usize, len: usize) -> Self {
        NaiveHam {
            lo,
            u: vec![0.0; len],
            l: vec![0.0; len],
        }
    }
}

impl AssignStep for NaiveHam {
    fn name(&self) -> &'static str {
        "naive-ham"
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn requirements(&self) -> Requirements {
        Requirements {
            cc: true,
            full_update: true,
            ..Requirements::default()
        }
    }

    fn init(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
    ) {
        let lo = self.lo;
        let (u, l) = (&mut self.u, &mut self.l);
        scalar_scan(sh, rows, lo, lo + a.len(), ctr, |li, row| {
            let t2 = top2_sqrt(row);
            a[li] = t2.idx1 as u32;
            u[li] = t2.val1;
            l[li] = t2.val2;
        });
    }

    fn round(
        &mut self,
        sh: &SharedRound,
        rows: &mut dyn BlockCursor,
        a: &mut [u32],
        ctr: &mut Counters,
        moved: &mut Vec<Moved>,
    ) {
        let lo = self.lo;
        for (li, a_li) in a.iter_mut().enumerate() {
            let ai = *a_li as usize;
            let gi = lo + li;
            self.u[li] += sh.p[ai];
            // the naive O(k) pass an unoptimised implementation performs
            let mut pmax = 0.0;
            for (j, &pj) in sh.p.iter().enumerate() {
                if j != ai && pj > pmax {
                    pmax = pj;
                }
            }
            self.l[li] -= pmax;
            let m = self.l[li].max(sh.s(ai) * 0.5);
            if m >= self.u[li] {
                continue;
            }
            self.u[li] = dist_ic(sh, rows, gi, ai, ctr);
            if m >= self.u[li] {
                continue;
            }
            let mut t2 = Top2::new();
            for j in 0..sh.k {
                let dj = if j == ai {
                    self.u[li]
                } else {
                    dist_ic(sh, rows, gi, j, ctr)
                };
                t2.push(j, dj);
            }
            self.u[li] = t2.val1;
            self.l[li] = t2.val2;
            if t2.idx1 != ai {
                moved.push(Moved {
                    i: gi as u32,
                    from: ai as u32,
                    to: t2.idx1 as u32,
                });
                *a_li = t2.idx1 as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::*;

    #[test]
    fn matches_sta_on_blobs() {
        assert_exact_vs_sta(
            |lo, len, _k, _g| Box::new(NaiveHam::new(lo, len)),
            400,
            6,
            8,
            107,
        );
    }
}
