//! Point-to-point squared distances: the innermost hot path.
//!
//! Three routes exist and all are exercised by the algorithms:
//!
//! 1. [`sqdist`] — direct `Σ(aᵢ−bᵢ)²` over 8-wide lanes, used whenever a
//!    *single* distance is needed (bound tightening). Numerically the
//!    most accurate.
//! 2. [`sqdist_from_parts`] / [`sqdist_batch_block`] — the norm
//!    decomposition `‖x‖² − 2x·c + ‖c‖²`, used for batch scans where the
//!    norms are amortised (sta's full assignment, init, the cc matrix).
//! 3. [`sqdist_argmin_block`] — the fused variant of route 2 for callers
//!    that only need labels + nearest distances: it runs the same panel
//!    micro-kernel over [`gemm::NB`]-wide strips and folds each strip
//!    into a running argmin, never materialising the `m×k` matrix.
//!    Bit-identical to `sqdist_batch_block` + `argmin` per row.

use super::gemm;
use super::norms::{reduce8, LANES};

/// Direct squared Euclidean distance, 8 independent lanes
/// (difference then square per lane; fixed tree reduction + tail).
#[inline]
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        let xa: &[f64; LANES] = xa.try_into().expect("LANES chunk");
        let xb: &[f64; LANES] = xb.try_into().expect("LANES chunk");
        for l in 0..LANES {
            let diff = xa[l] - xb[l];
            acc[l] += diff * diff;
        }
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        let diff = x - y;
        tail += diff * diff;
    }
    reduce8(acc) + tail
}

/// Squared distance from pre-computed parts; clamped at zero because the
/// decomposition can go slightly negative under cancellation.
#[inline]
pub fn sqdist_from_parts(xnorm: f64, cnorm: f64, xdotc: f64) -> f64 {
    (xnorm + cnorm - 2.0 * xdotc).max(0.0)
}

/// Batch squared distances from a block of `m` samples to all `k`
/// centroids, written into `out` (row-major `m×k`).
///
/// Uses the norm decomposition with a blocked matrix product so the
/// centroid panel stays cache-resident — this is the paper's §4.1.1
/// "BLAS" trick, implemented natively.
pub fn sqdist_batch_block(
    xs: &[f64],      // m×d samples
    xnorms: &[f64],  // m
    cs: &[f64],      // k×d centroids
    cnorms: &[f64],  // k
    d: usize,
    out: &mut [f64], // m×k
) {
    let m = xnorms.len();
    let k = cnorms.len();
    debug_assert_eq!(xs.len(), m * d);
    debug_assert_eq!(cs.len(), k * d);
    debug_assert_eq!(out.len(), m * k);
    // out ← X · Cᵀ
    gemm::matmul_nt(xs, cs, out, m, d, k);
    for (row, &xn) in out.chunks_exact_mut(k).zip(xnorms) {
        for (o, &cn) in row.iter_mut().zip(cnorms) {
            *o = (xn + cn - 2.0 * *o).max(0.0);
        }
    }
}

/// Fused batch-distance + argmin: for each of `m` samples, the index of
/// the nearest of `k` centroids (`labels`) and its squared distance
/// (`dists_sq`), without ever materialising the `m×k` distance matrix.
///
/// Works strip by strip: the same [`gemm::pack_b_panel`] /
/// [`gemm::matmul_nt_panel`] micro-kernel that backs
/// [`gemm::matmul_nt`] computes an `m×kw` dot-product strip
/// (`kw ≤ NB`), which is immediately folded into a running
/// first-lowest-index argmin. Because panel cells are stride-independent
/// and the strips walk `j` ascending with a strict `<`, the result is
/// **bit-identical** to `sqdist_batch_block` into a full matrix followed
/// by [`argmin`](crate::linalg::argmin) per row — while touching only
/// `O(m·NB)` scratch.
pub fn sqdist_argmin_block(
    xs: &[f64],          // m×d samples
    xnorms: &[f64],      // m
    cs: &[f64],          // k×d centroids
    cnorms: &[f64],      // k
    d: usize,
    labels: &mut [u32],  // m
    dists_sq: &mut [f64], // m
) {
    let m = xnorms.len();
    let k = cnorms.len();
    debug_assert_eq!(xs.len(), m * d);
    debug_assert_eq!(cs.len(), k * d);
    assert_eq!(labels.len(), m);
    assert_eq!(dists_sq.len(), m);
    assert!(k > 0, "no centroids");
    labels.fill(0);
    dists_sq.fill(f64::INFINITY);
    let mut packed = Vec::new();
    let mut strip = vec![0.0; m * gemm::NB.min(k)];
    let mut j0 = 0;
    while j0 < k {
        let kw = gemm::NB.min(k - j0);
        gemm::pack_b_panel(cs, d, j0, kw, &mut packed);
        gemm::matmul_nt_panel(xs, d, m, &packed, kw, &mut strip[..m * kw], kw);
        for i in 0..m {
            let xn = xnorms[i];
            let row = &strip[i * kw..(i + 1) * kw];
            let mut bj = labels[i];
            let mut bv = dists_sq[i];
            for (c, &xdotc) in row.iter().enumerate() {
                let sq = (xn + cnorms[j0 + c] - 2.0 * xdotc).max(0.0);
                if sq < bv {
                    bv = sq;
                    bj = (j0 + c) as u32;
                }
            }
            labels[i] = bj;
            dists_sq[i] = bv;
        }
        j0 += kw;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::{dot, sqnorm, sqnorms_rows};
    use crate::linalg::{argmin, reference};

    #[test]
    fn sqdist_matches_naive() {
        for n in [1usize, 2, 4, 5, 8, 9, 16, 17, 33] {
            let a: Vec<f64> = (0..n).map(|i| i as f64 * 0.3).collect();
            let b: Vec<f64> = (0..n).map(|i| 1.0 - i as f64 * 0.7).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            assert!((sqdist(&a, &b) - naive).abs() < 1e-12 * (1.0 + naive));
        }
    }

    #[test]
    fn sqdist_matches_reference_on_awkward_dims_both_widths() {
        for &d in reference::AWKWARD_DIMS {
            for widen in [false, true] {
                let mut a = reference::wave(d, 0.37);
                let mut b = reference::wave(d, 0.61);
                if widen {
                    reference::round_to_f32(&mut a);
                    reference::round_to_f32(&mut b);
                }
                let want = reference::sqdist(&a, &b);
                let got = sqdist(&a, &b);
                assert!(
                    (got - want).abs() <= 1e-12 * (1.0 + want.abs()),
                    "d={d} widen={widen}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn parts_equal_direct() {
        let a = [1.0, -2.0, 0.5];
        let b = [0.0, 3.0, -1.5];
        let via = sqdist_from_parts(sqnorm(&a), sqnorm(&b), dot(&a, &b));
        assert!((via - sqdist(&a, &b)).abs() < 1e-12);
    }

    #[test]
    fn parts_clamps_negative() {
        // identical vectors can produce tiny negative values in the
        // decomposition; the clamp must kick in
        assert_eq!(sqdist_from_parts(1.0, 1.0, 1.0 + 1e-17), 0.0);
    }

    #[test]
    fn batch_matches_pointwise() {
        let d = 5;
        let xs: Vec<f64> = (0..3 * d).map(|i| (i as f64).sin()).collect();
        let cs: Vec<f64> = (0..4 * d).map(|i| (i as f64 * 0.37).cos()).collect();
        let xn = sqnorms_rows(&xs, d);
        let cn = sqnorms_rows(&cs, d);
        let mut out = vec![0.0; 3 * 4];
        sqdist_batch_block(&xs, &xn, &cs, &cn, d, &mut out);
        for i in 0..3 {
            for j in 0..4 {
                let direct = sqdist(&xs[i * d..(i + 1) * d], &cs[j * d..(j + 1) * d]);
                assert!(
                    (out[i * 4 + j] - direct).abs() < 1e-10,
                    "mismatch at ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn fused_argmin_bit_identical_to_materialising_path() {
        // shapes straddling the NB strip boundary and tile remainders
        for (m, d, k) in [
            (1, 1, 1),
            (7, 3, 5),
            (13, 9, 64),
            (13, 9, 65),
            (33, 5, 130),
            (5, 784, 67),
        ] {
            let xs: Vec<f64> = (0..m * d).map(|i| (i as f64 * 0.193).sin()).collect();
            let cs: Vec<f64> = (0..k * d).map(|i| (i as f64 * 0.067).cos()).collect();
            let xn = sqnorms_rows(&xs, d);
            let cn = sqnorms_rows(&cs, d);
            let mut full = vec![0.0; m * k];
            sqdist_batch_block(&xs, &xn, &cs, &cn, d, &mut full);
            let mut labels = vec![u32::MAX; m];
            let mut dists = vec![0.0; m];
            sqdist_argmin_block(&xs, &xn, &cs, &cn, d, &mut labels, &mut dists);
            for i in 0..m {
                let row = &full[i * k..(i + 1) * k];
                let want = argmin(row).unwrap();
                assert_eq!(labels[i] as usize, want, "({m},{d},{k}) row {i} label");
                assert_eq!(
                    dists[i].to_bits(),
                    row[want].to_bits(),
                    "({m},{d},{k}) row {i} dist bits"
                );
            }
        }
    }

    #[test]
    fn fused_argmin_ties_pick_lowest_index() {
        // duplicated centroids across a strip boundary: first index wins
        let d = 2;
        let k = gemm::NB + 3;
        let mut cs = vec![0.0; k * d];
        for j in 0..k {
            cs[j * d] = 7.0; // all centroids identical
            cs[j * d + 1] = -7.0;
        }
        let xs = [1.0, 2.0];
        let xn = sqnorms_rows(&xs, d);
        let cn = sqnorms_rows(&cs, d);
        let mut labels = vec![u32::MAX; 1];
        let mut dists = vec![0.0; 1];
        sqdist_argmin_block(&xs, &xn, &cs, &cn, d, &mut labels, &mut dists);
        assert_eq!(labels[0], 0);
    }
}
