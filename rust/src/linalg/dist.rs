//! Point-to-point squared distances: the innermost hot path.
//!
//! Two routes exist and both are exercised by the algorithms:
//!
//! 1. [`sqdist`] — direct `Σ(aᵢ−bᵢ)²`, used whenever a *single* distance
//!    is needed (bound tightening). Numerically the most accurate.
//! 2. [`sqdist_from_parts`] / [`sqdist_batch_block`] — the norm
//!    decomposition `‖x‖² − 2x·c + ‖c‖²`, used for batch scans where the
//!    norms are amortised (sta's full assignment, init, the cc matrix).

use super::gemm;

/// Direct squared Euclidean distance, 4-way unrolled.
#[inline]
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        let d0 = a[i] - b[i];
        let d1 = a[i + 1] - b[i + 1];
        let d2 = a[i + 2] - b[i + 2];
        let d3 = a[i + 3] - b[i + 3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut tail = 0.0;
    for i in chunks * 4..n {
        let d = a[i] - b[i];
        tail += d * d;
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Squared distance from pre-computed parts; clamped at zero because the
/// decomposition can go slightly negative under cancellation.
#[inline]
pub fn sqdist_from_parts(xnorm: f64, cnorm: f64, xdotc: f64) -> f64 {
    (xnorm + cnorm - 2.0 * xdotc).max(0.0)
}

/// Batch squared distances from a block of `m` samples to all `k`
/// centroids, written into `out` (row-major `m×k`).
///
/// Uses the norm decomposition with a blocked matrix product so the
/// centroid block stays cache-resident — this is the paper's §4.1.1
/// "BLAS" trick, implemented natively.
pub fn sqdist_batch_block(
    xs: &[f64],      // m×d samples
    xnorms: &[f64],  // m
    cs: &[f64],      // k×d centroids
    cnorms: &[f64],  // k
    d: usize,
    out: &mut [f64], // m×k
) {
    let m = xnorms.len();
    let k = cnorms.len();
    debug_assert_eq!(xs.len(), m * d);
    debug_assert_eq!(cs.len(), k * d);
    debug_assert_eq!(out.len(), m * k);
    // out ← X · Cᵀ
    gemm::matmul_nt(xs, cs, out, m, d, k);
    for (row, &xn) in out.chunks_exact_mut(k).zip(xnorms) {
        for (o, &cn) in row.iter_mut().zip(cnorms) {
            *o = (xn + cn - 2.0 * *o).max(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::{dot, sqnorm, sqnorms_rows};

    #[test]
    fn sqdist_matches_naive() {
        for n in [1usize, 2, 4, 5, 9, 16, 33] {
            let a: Vec<f64> = (0..n).map(|i| i as f64 * 0.3).collect();
            let b: Vec<f64> = (0..n).map(|i| 1.0 - i as f64 * 0.7).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            assert!((sqdist(&a, &b) - naive).abs() < 1e-12 * (1.0 + naive));
        }
    }

    #[test]
    fn parts_equal_direct() {
        let a = [1.0, -2.0, 0.5];
        let b = [0.0, 3.0, -1.5];
        let via = sqdist_from_parts(sqnorm(&a), sqnorm(&b), dot(&a, &b));
        assert!((via - sqdist(&a, &b)).abs() < 1e-12);
    }

    #[test]
    fn parts_clamps_negative() {
        // identical vectors can produce tiny negative values in the
        // decomposition; the clamp must kick in
        assert_eq!(sqdist_from_parts(1.0, 1.0, 1.0 + 1e-17), 0.0);
    }

    #[test]
    fn batch_matches_pointwise() {
        let d = 5;
        let xs: Vec<f64> = (0..3 * d).map(|i| (i as f64).sin()).collect();
        let cs: Vec<f64> = (0..4 * d).map(|i| (i as f64 * 0.37).cos()).collect();
        let xn = sqnorms_rows(&xs, d);
        let cn = sqnorms_rows(&cs, d);
        let mut out = vec![0.0; 3 * 4];
        sqdist_batch_block(&xs, &xn, &cs, &cn, d, &mut out);
        for i in 0..3 {
            for j in 0..4 {
                let direct = sqdist(&xs[i * d..(i + 1) * d], &cs[j * d..(j + 1) * d]);
                assert!(
                    (out[i * 4 + j] - direct).abs() < 1e-10,
                    "mismatch at ({i},{j})"
                );
            }
        }
    }
}
