//! Arg-min and top-2 (two smallest) selection over distance rows.
//!
//! Every bounding algorithm needs the *two* nearest centroids on a
//! bound-repair scan — `n₁(i)` to assign and `n₂(i)` for the new lower
//! bound — so top-2 selection is a first-class primitive here.

/// Index of the minimum value. Ties resolve to the lowest index; empty
/// slices return `None`.
#[inline]
pub fn argmin(xs: &[f64]) -> Option<usize> {
    if xs.is_empty() {
        return None;
    }
    let mut best = 0;
    let mut bv = xs[0];
    for (i, &v) in xs.iter().enumerate().skip(1) {
        if v < bv {
            bv = v;
            best = i;
        }
    }
    Some(best)
}

/// The two smallest values of a scan, with the index of the smallest.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Top2 {
    /// Index of the minimum.
    pub idx1: usize,
    /// Minimum value.
    pub val1: f64,
    /// Index of the second smallest (== `usize::MAX` until two values seen).
    pub idx2: usize,
    /// Second-smallest value (`f64::INFINITY` until two values seen).
    pub val2: f64,
}

impl Top2 {
    /// Start an empty scan.
    #[inline]
    pub fn new() -> Self {
        Top2 {
            idx1: usize::MAX,
            val1: f64::INFINITY,
            idx2: usize::MAX,
            val2: f64::INFINITY,
        }
    }

    /// Feed one (index, value) pair into the scan.
    #[inline]
    pub fn push(&mut self, idx: usize, val: f64) {
        if val < self.val1 {
            self.idx2 = self.idx1;
            self.val2 = self.val1;
            self.idx1 = idx;
            self.val1 = val;
        } else if val < self.val2 {
            self.idx2 = idx;
            self.val2 = val;
        }
    }
}

impl Default for Top2 {
    fn default() -> Self {
        Self::new()
    }
}

/// Top-2 over a whole row (indices are positions in the slice).
#[inline]
pub fn top2(xs: &[f64]) -> Top2 {
    let mut t = Top2::new();
    for (i, &v) in xs.iter().enumerate() {
        t.push(i, v);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmin_basics() {
        assert_eq!(argmin(&[]), None);
        assert_eq!(argmin(&[3.0]), Some(0));
        assert_eq!(argmin(&[3.0, 1.0, 2.0]), Some(1));
        // ties → lowest index
        assert_eq!(argmin(&[2.0, 1.0, 1.0]), Some(1));
    }

    #[test]
    fn top2_ordering() {
        let t = top2(&[5.0, 1.0, 3.0, 0.5, 9.0]);
        assert_eq!(t.idx1, 3);
        assert_eq!(t.val1, 0.5);
        assert_eq!(t.idx2, 1);
        assert_eq!(t.val2, 1.0);
    }

    #[test]
    fn top2_single_element() {
        let t = top2(&[4.0]);
        assert_eq!(t.idx1, 0);
        assert!(t.val2.is_infinite());
        assert_eq!(t.idx2, usize::MAX);
    }

    #[test]
    fn top2_duplicates() {
        let t = top2(&[2.0, 2.0, 2.0]);
        assert_eq!(t.idx1, 0);
        assert_eq!(t.idx2, 1);
        assert_eq!(t.val1, 2.0);
        assert_eq!(t.val2, 2.0);
    }

    #[test]
    fn top2_incremental_matches_batch() {
        let xs = [0.3, 0.9, 0.1, 0.7, 0.1, 0.05];
        let mut inc = Top2::new();
        for (i, &v) in xs.iter().enumerate() {
            inc.push(i, v);
        }
        assert_eq!(inc, top2(&xs));
    }
}
