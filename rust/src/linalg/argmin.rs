//! Arg-min and top-2 (two smallest) selection over distance rows.
//!
//! Every bounding algorithm needs the *two* nearest centroids on a
//! bound-repair scan — `n₁(i)` to assign and `n₂(i)` for the new lower
//! bound — so top-2 selection is a first-class primitive here.

use super::norms::LANES;

/// Index of the minimum value. Ties resolve to the lowest index; empty
/// slices return `None`.
///
/// Two-phase: an 8-lane running minimum finds the min *value* without
/// any cross-lane index bookkeeping (each lane's compare-and-keep
/// autovectorizes to a masked min), then one linear `position` pass
/// recovers the first index holding it — which is exactly the
/// lowest-index tie the old scalar scan returned.
#[inline]
pub fn argmin(xs: &[f64]) -> Option<usize> {
    if xs.is_empty() {
        return None;
    }
    let mut mins = [f64::INFINITY; LANES];
    let mut c = xs.chunks_exact(LANES);
    for chunk in c.by_ref() {
        let chunk: &[f64; LANES] = chunk.try_into().expect("LANES chunk");
        for l in 0..LANES {
            if chunk[l] < mins[l] {
                mins[l] = chunk[l];
            }
        }
    }
    let mut m = f64::INFINITY;
    for &v in mins.iter().chain(c.remainder()) {
        if v < m {
            m = v;
        }
    }
    // `position` can only miss if every element is NaN (distances never
    // are); fall back to index 0 to keep the Option contract total.
    Some(xs.iter().position(|&v| v == m).unwrap_or(0))
}

/// The two smallest values of a scan, with the index of the smallest.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Top2 {
    /// Index of the minimum.
    pub idx1: usize,
    /// Minimum value.
    pub val1: f64,
    /// Index of the second smallest (== `usize::MAX` until two values seen).
    pub idx2: usize,
    /// Second-smallest value (`f64::INFINITY` until two values seen).
    pub val2: f64,
}

impl Top2 {
    /// Start an empty scan.
    #[inline]
    pub fn new() -> Self {
        Top2 {
            idx1: usize::MAX,
            val1: f64::INFINITY,
            idx2: usize::MAX,
            val2: f64::INFINITY,
        }
    }

    /// Feed one (index, value) pair into the scan.
    #[inline]
    pub fn push(&mut self, idx: usize, val: f64) {
        if val < self.val1 {
            self.idx2 = self.idx1;
            self.val2 = self.val1;
            self.idx1 = idx;
            self.val1 = val;
        } else if val < self.val2 {
            self.idx2 = idx;
            self.val2 = val;
        }
    }
}

impl Default for Top2 {
    fn default() -> Self {
        Self::new()
    }
}

/// Top-2 over a whole row (indices are positions in the slice).
#[inline]
pub fn top2(xs: &[f64]) -> Top2 {
    let mut t = Top2::new();
    for (i, &v) in xs.iter().enumerate() {
        t.push(i, v);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmin_basics() {
        assert_eq!(argmin(&[]), None);
        assert_eq!(argmin(&[3.0]), Some(0));
        assert_eq!(argmin(&[3.0, 1.0, 2.0]), Some(1));
        // ties → lowest index
        assert_eq!(argmin(&[2.0, 1.0, 1.0]), Some(1));
    }

    #[test]
    fn argmin_matches_reference_across_lane_boundaries() {
        use crate::linalg::reference;
        for n in [1usize, 2, 7, 8, 9, 15, 16, 17, 31, 33, 100] {
            // pseudo-random with deliberate duplicates
            let xs: Vec<f64> = (0..n).map(|i| ((i * 7919) % 13) as f64 * 0.5).collect();
            assert_eq!(argmin(&xs), reference::argmin(&xs), "n={n}");
        }
    }

    #[test]
    fn argmin_tie_across_lane_boundary_picks_first() {
        // minimum appears in a late lane of chunk 0 and again in chunk 1:
        // the position pass must return the earliest occurrence
        let mut xs = vec![5.0; 20];
        xs[6] = -1.0;
        xs[11] = -1.0;
        assert_eq!(argmin(&xs), Some(6));
    }

    #[test]
    fn top2_ordering() {
        let t = top2(&[5.0, 1.0, 3.0, 0.5, 9.0]);
        assert_eq!(t.idx1, 3);
        assert_eq!(t.val1, 0.5);
        assert_eq!(t.idx2, 1);
        assert_eq!(t.val2, 1.0);
    }

    #[test]
    fn top2_single_element() {
        let t = top2(&[4.0]);
        assert_eq!(t.idx1, 0);
        assert!(t.val2.is_infinite());
        assert_eq!(t.idx2, usize::MAX);
    }

    #[test]
    fn top2_duplicates() {
        let t = top2(&[2.0, 2.0, 2.0]);
        assert_eq!(t.idx1, 0);
        assert_eq!(t.idx2, 1);
        assert_eq!(t.val1, 2.0);
        assert_eq!(t.val2, 2.0);
    }

    #[test]
    fn top2_incremental_matches_batch() {
        let xs = [0.3, 0.9, 0.1, 0.7, 0.1, 0.05];
        let mut inc = Top2::new();
        for (i, &v) in xs.iter().enumerate() {
            inc.push(i, v);
        }
        assert_eq!(inc, top2(&xs));
    }
}
