//! A small blocked matrix product, `out ← A · Bᵀ`, built from one
//! shared **panel micro-kernel**.
//!
//! This is *not* a general BLAS: it is exactly the shape the batch
//! distance path needs (`X·Cᵀ` with tall-skinny `X` and modest `k`).
//! B is processed in panels of [`NB`] rows. Each panel is first packed
//! ([`pack_b_panel`]) so that the micro-kernel's inner loop reads
//! contiguous memory: full groups of [`NR`] B-rows are interleaved
//! t-major (`packed[.. t*NR + l ..] = B[j+l][t]`), remainder rows are
//! appended row-major. The compute ([`matmul_nt_panel`]) then walks
//! [`MR`]×[`NR`] register tiles — 16 independent accumulators whose
//! FMA chains overlap and autovectorize — with plain scalar edge loops
//! for the `m % MR` / `kw % NR` remainders.
//!
//! **Bit-level contract**: a cell's value depends only on its A-row and
//! B-row (and their position inside the panel), never on `m`, the
//! output stride, or which panel invocation computed it. That is what
//! lets the fused label scan
//! ([`sqdist_argmin_block`](crate::linalg::sqdist_argmin_block)) reuse
//! this micro-kernel on an `m×NB` strip and stay bit-identical to the
//! materialising [`matmul_nt`] path.

/// Register micro-tile height: rows of A per inner kernel.
pub(crate) const MR: usize = 4;
/// Register micro-tile width: rows of B (output columns) per inner kernel.
pub(crate) const NR: usize = 4;
/// Panel width: B-rows (output columns) packed and processed together.
/// Also the strip width of the fused label scan.
pub(crate) const NB: usize = 64;

/// Pack B-rows `[j0, j0+kw)` of a row-major `k×d` matrix for
/// [`matmul_nt_panel`]: full groups of [`NR`] rows interleaved t-major
/// (group `g` stores, for each `t`, the `NR` values `B[j0+g*NR+l][t]`
/// contiguously), then the `kw % NR` remainder rows row-major.
pub(crate) fn pack_b_panel(b: &[f64], d: usize, j0: usize, kw: usize, pack: &mut Vec<f64>) {
    debug_assert!((j0 + kw) * d <= b.len());
    pack.clear();
    pack.reserve(kw * d);
    let jfull = kw - kw % NR;
    let mut j = 0;
    while j < jfull {
        let rows = &b[(j0 + j) * d..(j0 + j + NR) * d];
        for t in 0..d {
            for l in 0..NR {
                pack.push(rows[l * d + t]);
            }
        }
        j += NR;
    }
    for jr in jfull..kw {
        pack.extend_from_slice(&b[(j0 + jr) * d..(j0 + jr + 1) * d]);
    }
}

/// Compute `out[i*stride + j] = A[i,:] · B[j0+j,:]` for `i ∈ [0, m)`,
/// `j ∈ [0, kw)`, with B supplied as [`pack_b_panel`] output. Every
/// cell is written exactly once (no pre-zeroing needed); per-cell
/// accumulation order is fixed by the tile geometry alone, so callers
/// at different strides get bit-identical cells.
pub(crate) fn matmul_nt_panel(
    a: &[f64],
    d: usize,
    m: usize,
    packed: &[f64],
    kw: usize,
    out: &mut [f64],
    stride: usize,
) {
    debug_assert_eq!(packed.len(), kw * d);
    debug_assert!(m * d <= a.len());
    debug_assert!(m == 0 || (m - 1) * stride + kw <= out.len());
    let jfull = kw - kw % NR;
    let ifull = m - m % MR;
    let mut i = 0;
    while i < ifull {
        let a0 = &a[i * d..(i + 1) * d];
        let a1 = &a[(i + 1) * d..(i + 2) * d];
        let a2 = &a[(i + 2) * d..(i + 3) * d];
        let a3 = &a[(i + 3) * d..(i + 4) * d];
        let mut j = 0;
        while j < jfull {
            // 4×4 register tile: 16 independent accumulators; each t
            // reads one contiguous NR-group of packed B.
            let grp = &packed[j * d..(j + NR) * d];
            let mut acc = [[0.0f64; NR]; MR];
            for t in 0..d {
                let pb: &[f64; NR] = grp[t * NR..t * NR + NR].try_into().expect("NR group");
                let av = [a0[t], a1[t], a2[t], a3[t]];
                for (r, accr) in acc.iter_mut().enumerate() {
                    for (c, slot) in accr.iter_mut().enumerate() {
                        *slot += av[r] * pb[c];
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                let row0 = (i + r) * stride + j;
                out[row0..row0 + NR].copy_from_slice(accr);
            }
            j += NR;
        }
        for jr in jfull..kw {
            let brow = &packed[jfull * d + (jr - jfull) * d..jfull * d + (jr - jfull + 1) * d];
            let mut s = [0.0f64; MR];
            for t in 0..d {
                let bv = brow[t];
                s[0] += a0[t] * bv;
                s[1] += a1[t] * bv;
                s[2] += a2[t] * bv;
                s[3] += a3[t] * bv;
            }
            for (r, sv) in s.iter().enumerate() {
                out[(i + r) * stride + jr] = *sv;
            }
        }
        i += MR;
    }
    while i < m {
        let arow = &a[i * d..(i + 1) * d];
        let mut j = 0;
        while j < jfull {
            let grp = &packed[j * d..(j + NR) * d];
            let mut s = [0.0f64; NR];
            for t in 0..d {
                let pb: &[f64; NR] = grp[t * NR..t * NR + NR].try_into().expect("NR group");
                for (c, sv) in s.iter_mut().enumerate() {
                    *sv += arow[t] * pb[c];
                }
            }
            out[i * stride + j..i * stride + j + NR].copy_from_slice(&s);
            j += NR;
        }
        for jr in jfull..kw {
            let brow = &packed[jfull * d + (jr - jfull) * d..jfull * d + (jr - jfull + 1) * d];
            let mut s = 0.0;
            for t in 0..d {
                s += arow[t] * brow[t];
            }
            out[i * stride + jr] = s;
        }
        i += 1;
    }
}

/// `out[m×k] ← A[m×d] · B[k×d]ᵀ`. Every output cell is unconditionally
/// written by the panel kernel, so `out` needs no pre-zeroing.
pub fn matmul_nt(a: &[f64], b: &[f64], out: &mut [f64], m: usize, d: usize, k: usize) {
    debug_assert_eq!(a.len(), m * d);
    debug_assert_eq!(b.len(), k * d);
    debug_assert_eq!(out.len(), m * k);
    if m == 0 || k == 0 {
        return;
    }
    let mut packed = Vec::new();
    let mut j0 = 0;
    while j0 < k {
        let kw = NB.min(k - j0);
        pack_b_panel(b, d, j0, kw, &mut packed);
        matmul_nt_panel(a, d, m, &packed, kw, &mut out[j0..], k);
        j0 += kw;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::reference;

    #[test]
    fn matches_naive_small() {
        for (m, d, k) in [(1, 1, 1), (2, 3, 4), (5, 7, 3), (33, 9, 65), (64, 2, 128)] {
            let a: Vec<f64> = (0..m * d).map(|i| (i as f64 * 0.173).sin()).collect();
            let b: Vec<f64> = (0..k * d).map(|i| (i as f64 * 0.071).cos()).collect();
            let mut out = vec![0.0; m * k];
            matmul_nt(&a, &b, &mut out, m, d, k);
            let want = reference::matmul_nt(&a, &b, m, d, k);
            for (got, want) in out.iter().zip(&want) {
                assert!((got - want).abs() < 1e-10, "({m},{d},{k})");
            }
        }
    }

    #[test]
    fn matches_reference_on_awkward_dims_both_widths() {
        let (m, k) = (13, 21); // both tile remainders non-zero
        for &d in reference::AWKWARD_DIMS {
            for widen in [false, true] {
                let mut a = reference::wave(m * d, 0.173);
                let mut b = reference::wave(k * d, 0.071);
                if widen {
                    reference::round_to_f32(&mut a);
                    reference::round_to_f32(&mut b);
                }
                let mut out = vec![0.0; m * k];
                matmul_nt(&a, &b, &mut out, m, d, k);
                let want = reference::matmul_nt(&a, &b, m, d, k);
                for (idx, (got, want)) in out.iter().zip(&want).enumerate() {
                    assert!(
                        (got - want).abs() <= 1e-10 * (1.0 + want.abs()),
                        "d={d} widen={widen} cell {idx}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn nan_poisoned_out_is_fully_overwritten_on_odd_shapes() {
        // no pre-zeroing: every cell must be unconditionally written,
        // including the m % MR and k % NR edge strips and d == 0
        for (m, d, k) in [
            (1, 1, 1),
            (3, 5, 7),
            (5, 1, 9),
            (7, 3, 66),
            (33, 9, 65),
            (130, 4, 67),
            (2, 0, 3),
        ] {
            let a: Vec<f64> = (0..m * d).map(|i| (i as f64 * 0.31).sin()).collect();
            let b: Vec<f64> = (0..k * d).map(|i| (i as f64 * 0.17).cos()).collect();
            let mut out = vec![f64::NAN; m * k];
            matmul_nt(&a, &b, &mut out, m, d, k);
            let want = reference::matmul_nt(&a, &b, m, d, k);
            for (idx, (got, want)) in out.iter().zip(&want).enumerate() {
                assert!(!got.is_nan(), "({m},{d},{k}) cell {idx} left unwritten");
                assert!((got - want).abs() < 1e-10, "({m},{d},{k}) cell {idx}");
            }
        }
    }

    #[test]
    fn panel_cells_are_stride_independent() {
        // the fused scan relies on it: same panel, different out strides
        // → bit-identical cells
        let (m, d, k) = (9, 11, NB + 5);
        let a: Vec<f64> = (0..m * d).map(|i| (i as f64 * 0.5).sin()).collect();
        let b: Vec<f64> = (0..k * d).map(|i| (i as f64 * 0.3).cos()).collect();
        let mut full = vec![0.0; m * k];
        matmul_nt(&a, &b, &mut full, m, d, k);
        let mut packed = Vec::new();
        let mut j0 = 0;
        while j0 < k {
            let kw = NB.min(k - j0);
            pack_b_panel(&b, d, j0, kw, &mut packed);
            let mut strip = vec![0.0; m * kw];
            matmul_nt_panel(&a, d, m, &packed, kw, &mut strip, kw);
            for i in 0..m {
                for c in 0..kw {
                    assert_eq!(
                        strip[i * kw + c].to_bits(),
                        full[i * k + j0 + c].to_bits(),
                        "cell ({i},{}) differs across strides",
                        j0 + c
                    );
                }
            }
            j0 += kw;
        }
    }

    #[test]
    fn zero_dims() {
        let mut out = vec![];
        matmul_nt(&[], &[], &mut out, 0, 3, 0);
        assert!(out.is_empty());
    }
}
