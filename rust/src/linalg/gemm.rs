//! A small blocked matrix product, `out ← A · Bᵀ`.
//!
//! This is *not* a general BLAS: it is exactly the shape the batch
//! distance path needs (`X·Cᵀ` with tall-skinny `X` and modest `k`), and
//! is tuned for that. Blocking keeps a tile of B resident in L1/L2 while
//! a strip of A streams through, which is where the paper's "use BLAS"
//! advice gets its speedup from.

/// Row tile height for A.
const MB: usize = 32;
/// Row tile height for B (columns of the output).
const NB: usize = 64;

/// `out[m×k] ← A[m×d] · B[k×d]ᵀ`, accumulating nothing (out overwritten).
pub fn matmul_nt(a: &[f64], b: &[f64], out: &mut [f64], m: usize, d: usize, k: usize) {
    debug_assert_eq!(a.len(), m * d);
    debug_assert_eq!(b.len(), k * d);
    debug_assert_eq!(out.len(), m * k);
    out.fill(0.0);
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + MB).min(m);
        let mut j0 = 0;
        while j0 < k {
            let j1 = (j0 + NB).min(k);
            // Micro-kernel over the tile: 2 rows of A × 2 rows of B per
            // step (4 accumulators) so each loaded element is reused
            // twice and the FMA chains overlap.
            let mut i = i0;
            while i + 2 <= i1 {
                let a0 = &a[i * d..(i + 1) * d];
                let a1 = &a[(i + 1) * d..(i + 2) * d];
                let mut j = j0;
                while j + 2 <= j1 {
                    let b0 = &b[j * d..(j + 1) * d];
                    let b1 = &b[(j + 1) * d..(j + 2) * d];
                    let (mut s00, mut s01, mut s10, mut s11) = (0.0, 0.0, 0.0, 0.0);
                    for t in 0..d {
                        let av0 = a0[t];
                        let av1 = a1[t];
                        let bv0 = b0[t];
                        let bv1 = b1[t];
                        s00 += av0 * bv0;
                        s01 += av0 * bv1;
                        s10 += av1 * bv0;
                        s11 += av1 * bv1;
                    }
                    out[i * k + j] = s00;
                    out[i * k + j + 1] = s01;
                    out[(i + 1) * k + j] = s10;
                    out[(i + 1) * k + j + 1] = s11;
                    j += 2;
                }
                if j < j1 {
                    let brow = &b[j * d..(j + 1) * d];
                    let (mut s0, mut s1) = (0.0, 0.0);
                    for t in 0..d {
                        s0 += a0[t] * brow[t];
                        s1 += a1[t] * brow[t];
                    }
                    out[i * k + j] = s0;
                    out[(i + 1) * k + j] = s1;
                }
                i += 2;
            }
            if i < i1 {
                let arow = &a[i * d..(i + 1) * d];
                for j in j0..j1 {
                    let brow = &b[j * d..(j + 1) * d];
                    let mut s = 0.0;
                    for t in 0..d {
                        s += arow[t] * brow[t];
                    }
                    out[i * k + j] = s;
                }
            }
            j0 = j1;
        }
        i0 = i1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[f64], b: &[f64], m: usize, d: usize, k: usize) -> Vec<f64> {
        let mut out = vec![0.0; m * k];
        for i in 0..m {
            for j in 0..k {
                out[i * k + j] = (0..d).map(|t| a[i * d + t] * b[j * d + t]).sum();
            }
        }
        out
    }

    #[test]
    fn matches_naive_small() {
        for (m, d, k) in [(1, 1, 1), (2, 3, 4), (5, 7, 3), (33, 9, 65), (64, 2, 128)] {
            let a: Vec<f64> = (0..m * d).map(|i| (i as f64 * 0.173).sin()).collect();
            let b: Vec<f64> = (0..k * d).map(|i| (i as f64 * 0.071).cos()).collect();
            let mut out = vec![0.0; m * k];
            matmul_nt(&a, &b, &mut out, m, d, k);
            let want = naive(&a, &b, m, d, k);
            for (got, want) in out.iter().zip(&want) {
                assert!((got - want).abs() < 1e-10, "({m},{d},{k})");
            }
        }
    }

    #[test]
    fn zero_dims() {
        let mut out = vec![];
        matmul_nt(&[], &[], &mut out, 0, 3, 0);
        assert!(out.is_empty());
    }
}
