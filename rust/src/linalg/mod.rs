//! Dense numerical kernels shared by every algorithm.
//!
//! All data is `f64` (the paper's experiments use double precision),
//! row-major. The crate builds these from scratch — no BLAS — but applies
//! the same engineering tricks the paper lists in §4.1.1: pre-computed
//! squared norms, `‖x−c‖² = ‖x‖² − 2x·c + ‖c‖²` decomposition, blocked
//! matrix products for the batch path, and unrolled inner loops.

pub mod argmin;
pub mod dist;
pub mod gemm;
pub mod norms;

pub use argmin::{argmin, top2, Top2};
pub use dist::{sqdist, sqdist_batch_block, sqdist_from_parts};
pub use norms::{dot, sqnorm, sqnorms_rows};
