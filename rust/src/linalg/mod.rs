//! Dense numerical kernels shared by every algorithm.
//!
//! All arithmetic is `f64` accumulation over row-major data (the paper's
//! experiments use double precision; the opt-in f32 *storage* path widens
//! at the data-source boundary, so these kernels never see f32). The
//! crate builds them from scratch — no BLAS — but applies the same
//! engineering tricks the paper lists in §4.1.1, organised around two
//! shapes the optimizer reliably vectorizes:
//!
//! - **Lane kernels** ([`dot`], [`sqnorm`], [`sqdist`], [`argmin`]): flat
//!   loops over `chunks_exact(LANES)` with `LANES = 8` independent
//!   accumulators and a scalar tail. Eight parallel FMA chains hide
//!   latency; the fixed tree reduction (`norms::reduce8`) makes the
//!   summation order — and therefore every bit of every result — a
//!   deterministic function of the input alone.
//! - **Tile kernels** ([`gemm`]): `out ← A·Bᵀ` via 4×4 register tiles
//!   over a packed B-panel, so the inner loop reads contiguous memory
//!   and keeps 16 accumulators live. [`sqdist_batch_block`] layers the
//!   `‖x‖² − 2x·c + ‖c‖²` decomposition on top; [`sqdist_argmin_block`]
//!   fuses the decomposition with a running argmin so label scans touch
//!   only an `m×NB` strip instead of the full `m×k` matrix.
//!
//! The fused and materialising batch paths share one panel micro-kernel
//! and one transform, so they are bit-identical by construction — the
//! determinism suite pins this.

pub mod argmin;
pub mod dist;
pub mod gemm;
pub mod norms;

pub use argmin::{argmin, top2, Top2};
pub use dist::{sqdist, sqdist_argmin_block, sqdist_batch_block, sqdist_from_parts};
pub use norms::{dot, sqnorm, sqnorms_rows};

#[cfg(test)]
pub(crate) mod reference {
    //! Pre-overhaul scalar kernels, kept as the oracle the lane/tile
    //! kernels are property-tested against (awkward dims, both storage
    //! widths). Test-only: never compiled into the library.

    /// Dimensions with awkward lane/tile tails, per the kernel test plan.
    pub const AWKWARD_DIMS: &[usize] = &[1, 2, 3, 5, 7, 9, 31, 33, 127, 784];

    /// Deterministic quasi-random test vector: `sin(i·f)`.
    pub fn wave(n: usize, f: f64) -> Vec<f64> {
        (0..n).map(|i| (i as f64 * f).sin()).collect()
    }

    /// Round every value to its nearest f32 — models the f32 storage
    /// path, where stored values are exactly representable in f32.
    pub fn round_to_f32(v: &mut [f64]) {
        for x in v {
            *x = *x as f32 as f64;
        }
    }

    /// Oracle dot product: naive left-to-right summation.
    pub fn dot(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    /// Oracle squared norm via [`dot`].
    pub fn sqnorm(a: &[f64]) -> f64 {
        dot(a, a)
    }

    /// Oracle squared distance: naive left-to-right summation.
    pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    }

    /// Naive triple-loop `A·Bᵀ`.
    pub fn matmul_nt(a: &[f64], b: &[f64], m: usize, d: usize, k: usize) -> Vec<f64> {
        let mut out = vec![0.0; m * k];
        for i in 0..m {
            for j in 0..k {
                let mut s = 0.0;
                for t in 0..d {
                    s += a[i * d + t] * b[j * d + t];
                }
                out[i * k + j] = s;
            }
        }
        out
    }

    /// The old linear-scan argmin (strict `<`, ties → lowest index).
    pub fn argmin(xs: &[f64]) -> Option<usize> {
        if xs.is_empty() {
            return None;
        }
        let mut best = 0;
        let mut bv = xs[0];
        for (i, &v) in xs.iter().enumerate().skip(1) {
            if v < bv {
                bv = v;
                best = i;
            }
        }
        Some(best)
    }
}
