//! Dot products and squared norms with 4-way unrolled inner loops.

/// Dot product of two equal-length slices, 4-way unrolled.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    // Four independent accumulators let the CPU overlap FMA latencies.
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut tail = 0.0;
    for i in chunks * 4..n {
        tail += a[i] * b[i];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Squared Euclidean norm.
#[inline]
pub fn sqnorm(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Squared norms of each row of a row-major `n×d` matrix.
pub fn sqnorms_rows(data: &[f64], d: usize) -> Vec<f64> {
    assert!(d > 0 && data.len() % d == 0, "data not a multiple of d");
    data.chunks_exact(d).map(sqnorm).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        // lengths around the unroll boundary
        for n in [0usize, 1, 3, 4, 5, 7, 8, 13, 64, 101] {
            let a: Vec<f64> = (0..n).map(|i| (i as f64) * 0.5 - 1.0).collect();
            let b: Vec<f64> = (0..n).map(|i| 2.0 - (i as f64) * 0.25).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-12 * (1.0 + naive.abs()));
        }
    }

    #[test]
    fn sqnorm_basic() {
        assert_eq!(sqnorm(&[3.0, 4.0]), 25.0);
        assert_eq!(sqnorm(&[]), 0.0);
    }

    #[test]
    fn sqnorms_rows_shape() {
        let m = [1.0, 0.0, 0.0, 2.0, 3.0, 4.0];
        let norms = sqnorms_rows(&m, 3);
        assert_eq!(norms, vec![1.0, 29.0]);
    }

    #[test]
    #[should_panic]
    fn sqnorms_rows_rejects_ragged() {
        sqnorms_rows(&[1.0, 2.0, 3.0], 2);
    }
}
