//! Dot products and squared norms: 8-wide lane kernels.
//!
//! Each kernel walks `chunks_exact(LANES)` with one independent
//! accumulator per lane and reduces the bank in a fixed tree order
//! ([`reduce8`]); the scalar tail is summed separately and added last.
//! The summation order is part of the crate's determinism story — it is
//! fixed by `LANES`, never by the caller or the thread count — but it
//! *differs* from a naive left-to-right sum, which is why the `.norms`
//! sidecar format version was bumped when these kernels landed (see
//! [`crate::data::ooc`]).

/// Lane width of the flat f64 kernels (8 × f64 = one ZMM register, two
/// YMM registers — wide enough that autovectorization has independent
/// FMA chains to overlap, narrow enough for the tail to stay cheap).
pub(crate) const LANES: usize = 8;

/// Reduce one bank of lane accumulators in a fixed tree order. The
/// order is part of each kernel's bit-level contract.
#[inline(always)]
pub(crate) fn reduce8(acc: [f64; LANES]) -> f64 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Dot product of two equal-length slices, 8 independent lanes.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        let xa: &[f64; LANES] = xa.try_into().expect("LANES chunk");
        let xb: &[f64; LANES] = xb.try_into().expect("LANES chunk");
        for l in 0..LANES {
            acc[l] += xa[l] * xb[l];
        }
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    reduce8(acc) + tail
}

/// Squared Euclidean norm. Bit-identical to `dot(a, a)` (same lane
/// assignment and reduction order) — the `.norms` sidecar and every
/// in-memory source rely on there being exactly one definition.
#[inline]
pub fn sqnorm(a: &[f64]) -> f64 {
    let mut acc = [0.0f64; LANES];
    let mut c = a.chunks_exact(LANES);
    for xa in c.by_ref() {
        let xa: &[f64; LANES] = xa.try_into().expect("LANES chunk");
        for l in 0..LANES {
            acc[l] += xa[l] * xa[l];
        }
    }
    let mut tail = 0.0;
    for x in c.remainder() {
        tail += x * x;
    }
    reduce8(acc) + tail
}

/// Squared norms of each row of a row-major `n×d` matrix.
pub fn sqnorms_rows(data: &[f64], d: usize) -> Vec<f64> {
    assert!(d > 0 && data.len() % d == 0, "data not a multiple of d");
    data.chunks_exact(d).map(sqnorm).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::reference;

    #[test]
    fn dot_matches_naive() {
        // lengths around the lane boundary
        for n in [0usize, 1, 3, 4, 5, 7, 8, 9, 13, 15, 16, 17, 64, 101] {
            let a: Vec<f64> = (0..n).map(|i| (i as f64) * 0.5 - 1.0).collect();
            let b: Vec<f64> = (0..n).map(|i| 2.0 - (i as f64) * 0.25).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-12 * (1.0 + naive.abs()));
        }
    }

    #[test]
    fn dot_and_sqnorm_match_reference_on_awkward_dims_both_widths() {
        for &d in reference::AWKWARD_DIMS {
            for widen in [false, true] {
                let mut a = reference::wave(d, 0.37);
                let mut b = reference::wave(d, 0.61);
                if widen {
                    reference::round_to_f32(&mut a);
                    reference::round_to_f32(&mut b);
                }
                let want = reference::dot(&a, &b);
                let got = dot(&a, &b);
                assert!(
                    (got - want).abs() <= 1e-12 * (1.0 + want.abs()),
                    "dot d={d} widen={widen}: {got} vs {want}"
                );
                let wn = reference::sqnorm(&a);
                let gn = sqnorm(&a);
                assert!(
                    (gn - wn).abs() <= 1e-12 * (1.0 + wn.abs()),
                    "sqnorm d={d} widen={widen}: {gn} vs {wn}"
                );
            }
        }
    }

    #[test]
    fn sqnorm_is_bit_identical_to_dot_with_itself() {
        for &d in reference::AWKWARD_DIMS {
            let a = reference::wave(d, 0.29);
            assert_eq!(sqnorm(&a).to_bits(), dot(&a, &a).to_bits(), "d={d}");
        }
    }

    #[test]
    fn sqnorm_basic() {
        assert_eq!(sqnorm(&[3.0, 4.0]), 25.0);
        assert_eq!(sqnorm(&[]), 0.0);
    }

    #[test]
    fn sqnorms_rows_shape() {
        let m = [1.0, 0.0, 0.0, 2.0, 3.0, 4.0];
        let norms = sqnorms_rows(&m, 3);
        assert_eq!(norms, vec![1.0, 29.0]);
    }

    #[test]
    #[should_panic]
    fn sqnorms_rows_rejects_ragged() {
        sqnorms_rows(&[1.0, 2.0, 3.0], 2);
    }
}
