//! A persistent, dependency-free worker pool.
//!
//! Spawned once per [`Engine`](crate::coordinator::Engine) and parked
//! between rounds, the pool replaces the seed's per-round
//! `thread::scope` spawning: dispatching a round costs one mutex +
//! condvar broadcast instead of `T−1` OS thread creations, which is what
//! lets the *whole* round — assignment scan, delta update, and every
//! centroid-side build — run on the same threads.
//!
//! ## Determinism contract
//!
//! Every helper here preserves bit-identical results across pool widths:
//!
//! * [`WorkerPool::for_each_chunk`], [`WorkerPool::run_tasks`] and
//!   [`WorkerPool::run_tasks_ordered`] hand out work dynamically, but
//!   each item is processed exactly once with math that does not depend
//!   on which worker ran it — callers only use them for element-wise
//!   (non-reducing) writes or per-task state. `run_tasks_ordered` goes
//!   one step further: the *claim order* itself is caller-chosen (the
//!   scan scheduler's LPT ranking), which is free for the same reason —
//!   order shapes overlap in time, never a result.
//! * Reductions (counter merges, partial centroid sums) are performed by
//!   the *callers*, serially, in shard/chunk order, with chunk geometry
//!   derived from the item count alone — never from the pool width.
//!
//! The closure handed to [`WorkerPool::broadcast`] is lifetime-erased
//! while it runs on the workers; soundness rests on `broadcast` not
//! returning until every worker has finished the call (and on waiting
//! out the workers even when the caller's own share panics).

use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// OS threads ever spawned by pools in this process — lets tests assert
/// that a shared [`Runtime`](crate::runtime::Runtime) amortises
/// spawning across fits instead of re-spawning per engine.
static THREADS_SPAWNED: AtomicUsize = AtomicUsize::new(0);

/// Total pool worker threads spawned by this process so far.
pub fn threads_spawned_total() -> usize {
    THREADS_SPAWNED.load(Ordering::SeqCst)
}

/// The type-erased closure workers execute; the argument is the worker
/// index in `0..width` (0 is the caller).
type Task = dyn Fn(usize) + Sync;

/// Dispatch state shared between the caller and the workers.
struct Slot {
    /// Bumped to publish a new job; workers compare against their last
    /// seen value, so spurious condvar wakeups are harmless.
    epoch: u64,
    /// The current job (present iff a broadcast is in flight).
    job: Option<&'static Task>,
    /// Workers still executing the current job.
    active: usize,
    /// A worker's share of the job panicked.
    panicked: bool,
    /// Pool is being dropped.
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    work: Condvar,
    done: Condvar,
}

/// Persistent worker pool of `width` participants: `width − 1` parked OS
/// threads plus the calling thread, which always executes share 0.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Serialises dispatches: `broadcast` is callable through `&self`
    /// (the pool is `Sync`), so without this gate two threads could
    /// clobber the single job slot mid-flight — which would break the
    /// lifetime-erasure safety argument, not just determinism.
    gate: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `threads.max(1)` participants (the caller counts
    /// as one, so `threads == 1` spawns no OS threads at all).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                epoch: 0,
                job: None,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles: Vec<JoinHandle<()>> = (1..threads)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("eakm-pool-{w}"))
                    .spawn(move || worker_loop(&shared, w))
                    .expect("spawn pool worker")
            })
            .collect();
        THREADS_SPAWNED.fetch_add(handles.len(), Ordering::SeqCst);
        WorkerPool {
            shared,
            gate: Mutex::new(()),
            handles,
        }
    }

    /// A width-1 pool: every helper runs inline on the caller. Used by
    /// the serial convenience wrappers; costs one `Arc` allocation and
    /// spawns nothing.
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Number of participants (worker threads + the caller).
    pub fn width(&self) -> usize {
        self.handles.len() + 1
    }

    /// Run `f(w)` once per participant `w ∈ 0..width`, concurrently, and
    /// return when every call has finished. The caller runs `f(0)`.
    /// Concurrent broadcasts from different threads are serialised;
    /// nested broadcasts (calling `broadcast` from inside `f`) deadlock
    /// and are not supported.
    pub fn broadcast<F: Fn(usize) + Sync>(&self, f: F) {
        if self.handles.is_empty() {
            f(0);
            return;
        }
        // One dispatch at a time; survive poisoning (a panicked
        // broadcast leaves the slot quiescent — see below).
        let _gate = self
            .gate
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        // Erase the closure's lifetime for the job slot. Sound because
        // this function does not return (or unwind) until every worker
        // has finished running `task`, so the borrow of `f` stays live
        // for as long as any worker can observe it.
        let task: &Task = &f;
        let task = unsafe { std::mem::transmute::<&Task, &'static Task>(task) };
        {
            let mut slot = self.shared.slot.lock().unwrap();
            debug_assert_eq!(slot.active, 0, "nested or unfinished broadcast");
            slot.job = Some(task);
            slot.active = self.handles.len();
            slot.epoch += 1;
            self.shared.work.notify_all();
        }
        // The caller is participant 0. Catch a panic so we still wait
        // out the workers (they may be executing the borrowed closure).
        let caller = catch_unwind(AssertUnwindSafe(|| f(0)));
        let mut slot = self.shared.slot.lock().unwrap();
        while slot.active != 0 {
            slot = self.shared.done.wait(slot).unwrap();
        }
        slot.job = None;
        let worker_panicked = std::mem::take(&mut slot.panicked);
        drop(slot);
        if let Err(payload) = caller {
            resume_unwind(payload);
        }
        if worker_panicked {
            panic!("worker thread panicked during broadcast");
        }
    }

    /// Process `0..n` as dynamically scheduled `[lo, hi)` chunks of at
    /// least `min_chunk` elements. Chunks are claimed with an atomic
    /// counter, so the *partition* of work across workers varies between
    /// runs — callers must restrict `f` to element-wise writes whose
    /// value does not depend on the enclosing chunk (see module docs).
    pub fn for_each_chunk<F>(&self, n: usize, min_chunk: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let width = self.width();
        if width == 1 || n <= min_chunk {
            f(0, n);
            return;
        }
        // ~4 chunks per participant: dynamic balancing, low contention.
        let chunk = min_chunk.max(n / (4 * width)).max(1);
        let next = AtomicUsize::new(0);
        self.broadcast(|_w| loop {
            let lo = next.fetch_add(chunk, Ordering::Relaxed);
            if lo >= n {
                break;
            }
            f(lo, (lo + chunk).min(n));
        });
    }

    /// As [`WorkerPool::for_each_chunk`], but with the chunk size fixed
    /// by the caller instead of scaled by the pool width — chunk
    /// *geometry* is then a pure function of `(n, chunk)`, which the
    /// label scans use to keep cursor-open behaviour identical at any
    /// width (see [`sched::label_chunk`](crate::coordinator::sched::label_chunk)).
    /// Claiming is still dynamic; callers must restrict `f` to
    /// element-wise writes as for `for_each_chunk`.
    pub fn for_each_chunk_exact<F>(&self, n: usize, chunk: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let chunk = chunk.max(1);
        if self.handles.is_empty() || n <= chunk {
            // same chunk boundaries serially, so per-chunk side effects
            // (cursor opens, window refills) match the parallel path
            let mut lo = 0;
            while lo < n {
                f(lo, (lo + chunk).min(n));
                lo += chunk;
            }
            return;
        }
        let next = AtomicUsize::new(0);
        self.broadcast(|_w| loop {
            let lo = next.fetch_add(chunk, Ordering::Relaxed);
            if lo >= n {
                break;
            }
            f(lo, (lo + chunk).min(n));
        });
    }

    /// Run `f(i, &mut tasks[i])` for every task, each exactly once, with
    /// tasks claimed dynamically by whichever participant is free.
    pub fn run_tasks<T, F>(&self, tasks: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        match tasks {
            [] => {}
            [one] => f(0, one),
            many => {
                if self.handles.is_empty() {
                    for (i, t) in many.iter_mut().enumerate() {
                        f(i, t);
                    }
                    return;
                }
                let list = SharedSliceMut::new(many);
                let next = AtomicUsize::new(0);
                self.broadcast(|_w| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= list.len() {
                        break;
                    }
                    // Sound: the atomic hands each index to exactly one
                    // participant.
                    let task = unsafe { &mut list.range(i, i + 1)[0] };
                    f(i, task);
                });
            }
        }
    }

    /// As [`WorkerPool::run_tasks`], but tasks are *claimed* in the
    /// order given by `order` (a permutation of `0..tasks.len()`): the
    /// next free participant takes `tasks[order[seq]]` for the next
    /// unclaimed `seq`. The scan scheduler passes its greedy LPT
    /// ranking here so expensive shards start first. Claim order never
    /// affects results — each task still runs exactly once with its own
    /// state — it only shapes which tasks overlap in time.
    pub fn run_tasks_ordered<T, F>(&self, tasks: &mut [T], order: &[usize], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        debug_assert_eq!(order.len(), tasks.len());
        debug_assert!({
            let mut seen = vec![false; tasks.len()];
            order
                .iter()
                .all(|&i| i < seen.len() && !std::mem::replace(&mut seen[i], true))
        });
        match tasks {
            [] => {}
            [one] => f(0, one),
            many => {
                if self.handles.is_empty() {
                    let list = SharedSliceMut::new(many);
                    for &i in order {
                        // Sound: `order` is a permutation, so each task
                        // is borrowed exactly once.
                        let task = unsafe { &mut list.range(i, i + 1)[0] };
                        f(i, task);
                    }
                    return;
                }
                let list = SharedSliceMut::new(many);
                let next = AtomicUsize::new(0);
                self.broadcast(|_w| loop {
                    let seq = next.fetch_add(1, Ordering::Relaxed);
                    if seq >= order.len() {
                        break;
                    }
                    let i = order[seq];
                    // Sound: the atomic hands each seq — and `order` is
                    // a permutation, so each index — to exactly one
                    // participant.
                    let task = unsafe { &mut list.range(i, i + 1)[0] };
                    f(i, task);
                });
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, widx: usize) {
    let mut seen = 0u64;
    loop {
        let task = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if slot.shutdown {
                    return;
                }
                if slot.epoch != seen {
                    seen = slot.epoch;
                    break slot.job.expect("job published with epoch");
                }
                slot = shared.work.wait(slot).unwrap();
            }
        };
        let result = catch_unwind(AssertUnwindSafe(|| task(widx)));
        let mut slot = shared.slot.lock().unwrap();
        if result.is_err() {
            slot.panicked = true;
        }
        slot.active -= 1;
        if slot.active == 0 {
            shared.done.notify_all();
        }
    }
}

/// A `&mut [T]` that can be carved into disjoint pieces from multiple
/// workers. The *caller* is responsible for disjointness; the type only
/// centralises the pointer bookkeeping so call sites stay readable.
pub struct SharedSliceMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Sync for SharedSliceMut<'_, T> {}
unsafe impl<T: Send> Send for SharedSliceMut<'_, T> {}

impl<'a, T> SharedSliceMut<'a, T> {
    /// Wrap a mutable slice; the borrow lasts as long as the wrapper.
    pub fn new(slice: &'a mut [T]) -> Self {
        SharedSliceMut {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: PhantomData,
        }
    }

    /// Length of the underlying slice.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the underlying slice is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Reborrow `[lo, hi)` mutably.
    ///
    /// # Safety
    /// Concurrent callers must use pairwise-disjoint ranges, and no other
    /// access to those elements may overlap the returned borrow.
    pub unsafe fn range(&self, lo: usize, hi: usize) -> &'a mut [T] {
        debug_assert!(lo <= hi && hi <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo)
    }

    /// Write one element.
    ///
    /// # Safety
    /// No other participant may access index `i` concurrently.
    pub unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len);
        self.ptr.add(i).write(value);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn broadcast_runs_every_participant() {
        for threads in [1, 2, 4, 7] {
            let pool = WorkerPool::new(threads);
            let mask = AtomicU64::new(0);
            pool.broadcast(|w| {
                mask.fetch_or(1 << w, Ordering::Relaxed);
            });
            assert_eq!(mask.load(Ordering::Relaxed), (1u64 << threads) - 1);
        }
    }

    #[test]
    fn pool_is_reusable_across_rounds() {
        let pool = WorkerPool::new(4);
        let hits = AtomicUsize::new(0);
        for _ in 0..100 {
            pool.broadcast(|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 400);
    }

    #[test]
    fn for_each_chunk_covers_exactly_once() {
        for threads in [1, 3, 8] {
            let pool = WorkerPool::new(threads);
            let n = 1013;
            let mut seen = vec![0u8; n];
            {
                let cells = SharedSliceMut::new(&mut seen);
                pool.for_each_chunk(n, 16, |lo, hi| {
                    let part = unsafe { cells.range(lo, hi) };
                    for v in part.iter_mut() {
                        *v += 1;
                    }
                });
            }
            assert!(seen.iter().all(|&v| v == 1), "threads={threads}");
        }
    }

    #[test]
    fn for_each_chunk_handles_empty_and_tiny() {
        let pool = WorkerPool::new(4);
        pool.for_each_chunk(0, 8, |_, _| panic!("no work expected"));
        let count = AtomicUsize::new(0);
        pool.for_each_chunk(3, 64, |lo, hi| {
            count.fetch_add(hi - lo, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn for_each_chunk_exact_covers_exactly_once() {
        for threads in [1, 3, 8] {
            let pool = WorkerPool::new(threads);
            let n = 1013;
            let mut seen = vec![0u8; n];
            {
                let cells = SharedSliceMut::new(&mut seen);
                pool.for_each_chunk_exact(n, 64, |lo, hi| {
                    // chunk geometry is width-independent: every chunk
                    // but the tail spans exactly 64 rows
                    assert!(hi - lo == 64 || hi == n);
                    assert_eq!(lo % 64, 0);
                    let part = unsafe { cells.range(lo, hi) };
                    for v in part.iter_mut() {
                        *v += 1;
                    }
                });
            }
            assert!(seen.iter().all(|&v| v == 1), "threads={threads}");
        }
        WorkerPool::new(4).for_each_chunk_exact(0, 8, |_, _| panic!("no work expected"));
    }

    #[test]
    fn run_tasks_ordered_runs_each_task_once() {
        for threads in [1, 2, 8] {
            let pool = WorkerPool::new(threads);
            let mut tasks: Vec<u32> = vec![0; 57];
            // reverse claim order: results must be unaffected
            let order: Vec<usize> = (0..tasks.len()).rev().collect();
            pool.run_tasks_ordered(&mut tasks, &order, |i, t| *t += 1 + i as u32);
            for (i, t) in tasks.iter().enumerate() {
                assert_eq!(*t, 1 + i as u32, "threads={threads}");
            }
        }
    }

    #[test]
    fn run_tasks_ordered_serial_claims_in_order() {
        let pool = WorkerPool::new(1);
        let mut tasks: Vec<u32> = vec![0; 5];
        let order = [3usize, 1, 4, 0, 2];
        let claimed = Mutex::new(Vec::new());
        pool.run_tasks_ordered(&mut tasks, &order, |i, _| {
            claimed.lock().unwrap().push(i);
        });
        assert_eq!(*claimed.lock().unwrap(), order);
    }

    #[test]
    fn run_tasks_gives_each_task_to_one_worker() {
        for threads in [1, 2, 8] {
            let pool = WorkerPool::new(threads);
            let mut tasks: Vec<u32> = vec![0; 57];
            pool.run_tasks(&mut tasks, |i, t| *t += 1 + i as u32);
            for (i, t) in tasks.iter().enumerate() {
                assert_eq!(*t, 1 + i as u32);
            }
        }
    }

    #[test]
    fn concurrent_broadcasts_are_serialised() {
        // the pool is Sync: dispatches from several threads must queue,
        // never clobber each other's job slot
        let pool = WorkerPool::new(3);
        let hits = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..50 {
                        pool.broadcast(|_| {
                            hits.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4 * 50 * 3);
    }

    #[test]
    fn width_counts_the_caller() {
        assert_eq!(WorkerPool::new(0).width(), 1);
        assert_eq!(WorkerPool::serial().width(), 1);
        assert_eq!(WorkerPool::new(5).width(), 5);
    }

    #[test]
    #[should_panic(expected = "worker thread panicked")]
    fn worker_panic_propagates_to_caller() {
        let pool = WorkerPool::new(2);
        pool.broadcast(|w| {
            if w == 1 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn pool_survives_a_panicked_broadcast() {
        let pool = WorkerPool::new(2);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            pool.broadcast(|w| {
                if w == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(outcome.is_err());
        let hits = AtomicUsize::new(0);
        pool.broadcast(|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 2);
    }
}
