//! The XLA assignment backend: a drop-in replacement for the native
//! batch distance scan, executing the AOT-compiled Pallas/JAX kernel
//! through PJRT.
//!
//! Artifacts are compiled for a fixed `(block, d, k)` shape (XLA requires
//! static shapes); the backend pads the final partial block with +∞-safe
//! sentinel rows and slices the results back.

use std::path::{Path, PathBuf};

use crate::error::{EakmError, Result};
use crate::runtime::pjrt::PjrtRuntime;

/// Identifies one compiled artifact shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArtifactSpec {
    /// Sample block size the kernel was lowered for.
    pub block: usize,
    /// Dimension.
    pub d: usize,
    /// Number of centroids.
    pub k: usize,
}

impl ArtifactSpec {
    /// Conventional artifact filename, matching `python/compile/aot.py`.
    pub fn filename(&self) -> String {
        format!("assign_{}x{}x{}.hlo.txt", self.block, self.d, self.k)
    }
}

/// Per-row result of the assignment kernel.
#[derive(Clone, Debug, PartialEq)]
pub struct AssignResult {
    /// Index of the nearest centroid (`n₁`).
    pub idx: Vec<u32>,
    /// Distance to the nearest centroid (plain, not squared).
    pub d1: Vec<f64>,
    /// Distance to the second-nearest centroid.
    pub d2: Vec<f64>,
}

/// Executes the `assign` artifact for a fixed shape.
pub struct XlaAssignBackend {
    runtime: PjrtRuntime,
    path: PathBuf,
    spec: ArtifactSpec,
}

impl XlaAssignBackend {
    /// Load the artifact for `spec` from `artifact_dir`.
    pub fn load(artifact_dir: &Path, spec: ArtifactSpec) -> Result<Self> {
        let path = artifact_dir.join(spec.filename());
        if !path.exists() {
            return Err(EakmError::Runtime(format!(
                "artifact {} not found — run `make artifacts`",
                path.display()
            )));
        }
        let mut runtime = PjrtRuntime::cpu()?;
        runtime.load(&path)?; // compile eagerly so errors surface here
        Ok(XlaAssignBackend {
            runtime,
            path,
            spec,
        })
    }

    /// Artifact shape.
    pub fn spec(&self) -> ArtifactSpec {
        self.spec
    }

    /// Assign a batch of samples (row-major `m×d`, any `m`) to the
    /// nearest of `k` centroids. Pads the last block internally.
    pub fn assign(&mut self, xs: &[f64], centroids: &[f64]) -> Result<AssignResult> {
        let ArtifactSpec { block, d, k } = self.spec;
        if xs.len() % d != 0 {
            return Err(EakmError::Runtime(format!(
                "xs length {} not divisible by d={d}",
                xs.len()
            )));
        }
        if centroids.len() != k * d {
            return Err(EakmError::Runtime(format!(
                "centroids length {} != k*d = {}",
                centroids.len(),
                k * d
            )));
        }
        let m = xs.len() / d;
        let mut out = AssignResult {
            idx: Vec::with_capacity(m),
            d1: Vec::with_capacity(m),
            d2: Vec::with_capacity(m),
        };
        let mut padded = vec![0.0; block * d];
        let mut start = 0;
        while start < m {
            let stop = (start + block).min(m);
            let rows = stop - start;
            let chunk: &[f64] = if rows == block {
                &xs[start * d..stop * d]
            } else {
                padded[..rows * d].copy_from_slice(&xs[start * d..stop * d]);
                // pad with copies of the last row — harmless, sliced off below
                for r in rows..block {
                    padded.copy_within((rows - 1) * d..rows * d, r * d);
                }
                &padded
            };
            let exe = self.runtime.load(&self.path)?;
            let outputs =
                PjrtRuntime::execute_f64(exe, &[(chunk, &[block, d]), (centroids, &[k, d])])?;
            if outputs.len() != 3 {
                return Err(EakmError::Runtime(format!(
                    "expected 3 outputs (idx, d1, d2), got {}",
                    outputs.len()
                )));
            }
            out.idx
                .extend(outputs[0][..rows].iter().map(|&v| v as u32));
            out.d1.extend_from_slice(&outputs[1][..rows]);
            out.d2.extend_from_slice(&outputs[2][..rows]);
            start = stop;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filename_convention() {
        let spec = ArtifactSpec {
            block: 256,
            d: 8,
            k: 50,
        };
        assert_eq!(spec.filename(), "assign_256x8x50.hlo.txt");
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let err = XlaAssignBackend::load(
            Path::new("/definitely/not/here"),
            ArtifactSpec {
                block: 4,
                d: 2,
                k: 2,
            },
        );
        match err {
            Err(EakmError::Runtime(msg)) => assert!(msg.contains("make artifacts")),
            Err(other) => panic!("expected runtime error, got {other:?}"),
            Ok(_) => panic!("expected an error"),
        }
    }
}
