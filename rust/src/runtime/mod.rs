//! PJRT runtime: load AOT-compiled XLA artifacts (authored in JAX/Pallas
//! at build time, see `python/compile/`) and execute them from the Rust
//! hot path. Python never runs at clustering time.

pub mod backend;
pub mod pjrt;

pub use backend::{ArtifactSpec, XlaAssignBackend};
pub use pjrt::PjrtRuntime;
