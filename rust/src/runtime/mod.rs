//! The execution runtime.
//!
//! [`pool`] is the heart of the crate's parallelism: a persistent,
//! dependency-free worker pool, parked between dispatches. [`rt`] wraps
//! it as the shared, process-lifetime [`Runtime`] that any number of
//! fits and predicts reuse (engines can also own a private pool via
//! [`Engine::new`](crate::coordinator::Engine::new), the legacy path).
//! The coordinator runs *every* phase of a round on it — the sharded
//! assignment scan, the delta centroid update, and the per-round
//! centroid-side builds (`cc` matrix, annuli, group maxima, ns history)
//! — with deterministic shard-ordered merges, so results are
//! bit-identical at any thread count.
//!
//! The optional `xla` feature adds the PJRT backend: AOT-compiled XLA
//! artifacts (authored in JAX/Pallas at build time, see
//! `python/compile/`) executed from the Rust hot path. Python never runs
//! at clustering time. The feature is off by default because the
//! external `xla` crate is not available in the offline build (see
//! `rust/Cargo.toml`).

pub mod pool;
pub mod rt;

#[cfg(feature = "xla")]
pub mod backend;
#[cfg(feature = "xla")]
pub mod pjrt;

#[cfg(feature = "xla")]
pub use backend::{ArtifactSpec, XlaAssignBackend};
#[cfg(feature = "xla")]
pub use pjrt::PjrtRuntime;
pub use pool::{SharedSliceMut, WorkerPool};
pub use rt::Runtime;
