//! Thin wrapper around the `xla` crate's PJRT CPU client.
//!
//! Interchange format is **HLO text** (not serialised protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while
//! the text parser reassigns ids cleanly (see /opt/xla-example/README.md
//! and python/compile/aot.py).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{EakmError, Result};

/// A PJRT CPU client plus a cache of compiled executables keyed by
/// artifact path (compilation is expensive; each artifact is compiled
/// exactly once per process).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create a CPU-backed runtime.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| EakmError::Runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(PjrtRuntime {
            client,
            cache: HashMap::new(),
        })
    }

    /// Platform name ("cpu" here; "tpu" with a TPU plugin).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&mut self, path: &Path) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(path) {
            let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
                EakmError::Runtime(format!("parse HLO text {}: {e}", path.display()))
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| EakmError::Runtime(format!("compile {}: {e}", path.display())))?;
            self.cache.insert(path.to_path_buf(), exe);
        }
        Ok(&self.cache[path])
    }

    /// Execute a loaded artifact on row-major f64 inputs, returning the
    /// flattened f64 outputs of the result tuple.
    ///
    /// `inputs` are `(data, dims)` pairs; artifacts are lowered with
    /// `return_tuple=True`, so the single result is always a tuple.
    pub fn execute_f64(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[(&[f64], &[usize])],
    ) -> Result<Vec<Vec<f64>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims_i64)
                    .map_err(|e| EakmError::Runtime(format!("reshape input: {e}")))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| EakmError::Runtime(format!("execute: {e}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| EakmError::Runtime(format!("to_literal: {e}")))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| EakmError::Runtime(format!("to_tuple: {e}")))?;
        parts
            .into_iter()
            .map(|p| {
                // outputs may be f64 or i32 (arg-min indices) — normalise
                // everything to f64 for a uniform API
                match p.to_vec::<f64>() {
                    Ok(v) => Ok(v),
                    Err(_) => p
                        .to_vec::<i32>()
                        .map(|v| v.into_iter().map(|x| x as f64).collect())
                        .map_err(|e| EakmError::Runtime(format!("output convert: {e}"))),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn load_missing_artifact_errors() {
        let mut rt = PjrtRuntime::cpu().unwrap();
        let err = rt.load(Path::new("/nonexistent/foo.hlo.txt"));
        assert!(err.is_err());
    }
}
