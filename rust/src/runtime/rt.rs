//! [`Runtime`] — the process-lifetime execution context.
//!
//! PR 1 made the worker pool persistent *per engine*; `Runtime` makes it
//! persistent *per process*: one pool, spawned once, reused by any
//! number of fits ([`Kmeans::fit`](crate::model::Kmeans::fit)) and
//! predicts ([`FittedModel::predict`](crate::model::FittedModel::predict)).
//! Under serving traffic this turns thread spawning from a per-request
//! cost into a startup cost.
//!
//! Results remain bit-identical across runtimes of any width — the pool
//! only executes element-wise work and order-fixed reductions (see
//! [`pool`](crate::runtime::pool)).

use crate::runtime::pool::WorkerPool;

/// Sentinel width: resolve from the machine's available parallelism.
/// (`config::AUTO_THREADS` is the same sentinel.)
pub const AUTO: usize = 0;

/// Resolve a thread-count sentinel: [`AUTO`] (0) becomes the machine's
/// available parallelism (≥ 1). The single resolver shared by
/// [`Runtime::new`] and
/// [`RunConfig::resolved_threads`](crate::config::RunConfig::resolved_threads).
pub fn resolve_threads(threads: usize) -> usize {
    if threads == AUTO {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    } else {
        threads
    }
}

/// A shared execution runtime owning one persistent [`WorkerPool`].
///
/// Cheap to pass by reference, `Sync` (dispatches from several threads
/// are serialised by the pool), and reusable for the life of the
/// process:
///
/// ```no_run
/// use eakm::prelude::*;
///
/// let rt = Runtime::new(4);
/// let data = eakm::data::synth::blobs(10_000, 8, 50, 0.05, 42);
/// let model = Kmeans::new(50).seed(7).fit(&rt, &data).unwrap();
/// let labels = model.predict(&rt, &data).unwrap(); // same pool, no respawn
/// # let _ = labels;
/// ```
pub struct Runtime {
    pool: WorkerPool,
}

impl Runtime {
    /// Spawn a runtime of `threads` participants ([`AUTO`] = the
    /// machine's available parallelism). The calling thread counts as
    /// one participant, so `threads == 1` spawns no OS threads.
    pub fn new(threads: usize) -> Self {
        Runtime {
            pool: WorkerPool::new(resolve_threads(threads)),
        }
    }

    /// A runtime sized from the machine's available parallelism.
    pub fn auto() -> Self {
        Self::new(AUTO)
    }

    /// A single-threaded runtime (everything runs on the caller).
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Resolved worker count (≥ 1).
    pub fn threads(&self) -> usize {
        self.pool.width()
    }

    /// The underlying pool (coordinator internals dispatch through it).
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn width_resolution() {
        assert_eq!(Runtime::new(3).threads(), 3);
        assert_eq!(Runtime::serial().threads(), 1);
        assert!(Runtime::auto().threads() >= 1);
    }

    #[test]
    fn pool_is_shared_and_reusable() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let rt = Runtime::new(4);
        let hits = AtomicUsize::new(0);
        for _ in 0..10 {
            rt.pool().broadcast(|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 40);
    }
}
