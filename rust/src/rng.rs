//! Deterministic, splittable pseudo-random number generation.
//!
//! The whole reproduction must be bit-deterministic given a seed (the
//! paper runs 10 fixed seeds per experiment), so we implement our own
//! small generator rather than depend on an external crate:
//! `xoshiro256++` seeded through `splitmix64`, the construction its
//! authors recommend.

/// splitmix64 step — used for seeding and for cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Deterministic, fast, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // xoshiro must not start from the all-zero state.
        if s == [0; 4] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Rng { s }
    }

    /// Derive an independent stream for a labelled sub-task.
    ///
    /// Mixing the label through splitmix64 keeps streams decorrelated, so
    /// e.g. each synthetic-dataset generator and each seeding run gets its
    /// own reproducible stream.
    pub fn split(&self, label: u64) -> Rng {
        let mut sm = self
            .s
            .iter()
            .fold(label ^ 0xA076_1D64_78BD_642F, |acc, &w| {
                let mut t = acc ^ w;
                splitmix64(&mut t)
            });
        Rng::new(splitmix64(&mut sm))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → uniform double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire reduction).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; determinism matters more than throughput here).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        // Avoid ln(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample `m` distinct indices from [0, n) (Floyd's algorithm),
    /// returned in insertion order.
    pub fn distinct(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "cannot sample {m} distinct from {n}");
        let mut chosen: Vec<usize> = Vec::with_capacity(m);
        for j in (n - m)..n {
            let t = self.below(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalised non-negative weights.
    /// Returns `None` if the total weight is not positive and finite.
    pub fn weighted(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) || !total.is_finite() {
            return None;
        }
        let mut target = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target < 0.0 {
                return Some(i);
            }
        }
        // Floating point slack: fall back to the last positive weight.
        weights.iter().rposition(|&w| w > 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_decorrelated() {
        let root = Rng::new(7);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(11);
        let n = 10;
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let x = r.below(n);
            assert!(x < n);
            counts[x] += 1;
        }
        for &c in &counts {
            // expectation 10_000, allow generous slack
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn distinct_yields_unique_indices() {
        let mut r = Rng::new(17);
        for _ in 0..100 {
            let v = r.distinct(50, 20);
            assert_eq!(v.len(), 20);
            let mut s = v.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 20);
            assert!(s.iter().all(|&x| x < 50));
        }
    }

    #[test]
    fn distinct_full_population() {
        let mut r = Rng::new(19);
        let mut v = r.distinct(10, 10);
        v.sort_unstable();
        assert_eq!(v, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(23);
        let w = [0.0, 3.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w).unwrap()] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[1] as f64 / counts[2] as f64;
        assert!((2.5..3.5).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn weighted_rejects_degenerate() {
        let mut r = Rng::new(29);
        assert!(r.weighted(&[0.0, 0.0]).is_none());
        assert!(r.weighted(&[f64::NAN]).is_none());
        assert!(r.weighted(&[f64::INFINITY]).is_none());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(31);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
