//! Shared machinery for the paper-table benchmark harnesses
//! (`rust/benches/table*.rs`) and the CLI's `grid`/`tables` commands:
//! workload construction, repeated measurement, and text table rendering
//! in the paper's layout.

pub mod check;
pub mod measure;
pub mod table;

pub use check::{check_bench_json, diff_bench_json, DiffRegression, TableSpec};
pub use measure::{measure, MeasureStats};
pub use table::TextTable;

use crate::data::synth::{generate, paper_datasets, DatasetSpec};
use crate::data::Dataset;

/// The scale at which grid benches run the paper datasets by default.
/// Full-size runs (`scale = 1.0`) reproduce Table 8 sizes exactly but
/// need the paper's 40-minute-per-run budget; the default keeps a full
/// 22-dataset × 2-k grid within a CI-sized budget while preserving each
/// dataset's d and structure. Override with `EAKM_SCALE`.
pub const DEFAULT_SCALE: f64 = 0.02;

/// Scale selected from the environment (`EAKM_SCALE`), else default.
pub fn env_scale() -> f64 {
    std::env::var("EAKM_SCALE")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .filter(|&s| s > 0.0 && s <= 1.0)
        .unwrap_or(DEFAULT_SCALE)
}

/// Number of seeds per experiment (paper: 10). `EAKM_SEEDS` overrides.
pub fn env_seeds() -> usize {
    std::env::var("EAKM_SEEDS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&s| s > 0)
        .unwrap_or(3)
}

/// k values for the grid (paper: 100 and 1000), scaled down with the
/// datasets so cluster populations stay comparable.
pub fn grid_ks(scale: f64) -> [usize; 2] {
    if scale >= 0.5 {
        [100, 1000]
    } else {
        // keep k/N roughly paper-like at small scale
        [50, 200]
    }
}

/// Generate the paper datasets at `scale` (optionally a filtered subset).
pub fn grid_datasets(scale: f64, filter: Option<&[usize]>) -> Vec<(DatasetSpec, Dataset)> {
    paper_datasets()
        .into_iter()
        .filter(|s| filter.map(|f| f.contains(&s.index)).unwrap_or(true))
        .map(|spec| {
            let ds = generate(&spec, scale, 0x00DA_7A5E);
            (spec, ds)
        })
        .collect()
}

/// Low-dimensional subset (paper: d < 20 → ham-family tables).
pub fn low_d_indices() -> Vec<usize> {
    paper_datasets()
        .iter()
        .filter(|s| s.d < 20)
        .map(|s| s.index)
        .collect()
}

/// High-dimensional subset (d ≥ 20).
pub fn high_d_indices() -> Vec<usize> {
    paper_datasets()
        .iter()
        .filter(|s| s.d >= 20)
        .map(|s| s.index)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_high_split_matches_paper() {
        let low = low_d_indices();
        let high = high_d_indices();
        assert_eq!(low.len() + high.len(), 22);
        assert_eq!(low, (1..=11).collect::<Vec<_>>()); // i–xi are d<20
        assert_eq!(high, (12..=22).collect::<Vec<_>>());
    }

    #[test]
    fn grid_datasets_filter_works() {
        let ds = grid_datasets(0.01, Some(&[1, 3]));
        assert_eq!(ds.len(), 2);
        assert_eq!(ds[0].0.name, "birch");
        assert_eq!(ds[1].0.name, "urand2");
    }

    #[test]
    fn scale_dependent_ks() {
        assert_eq!(grid_ks(1.0), [100, 1000]);
        assert_eq!(grid_ks(0.02), [50, 200]);
    }
}
