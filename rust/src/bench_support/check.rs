//! Schema checks for the machine-readable `BENCH_*.json` companions the
//! bench harnesses emit next to their text tables.
//!
//! CI's `bench-smoke` job runs the harnesses at a tiny scale and then
//! asserts — through the `bench_check` binary, which is a thin argv
//! wrapper over [`check_bench_json`] — that each JSON artifact parses,
//! identifies the right bench, and contains its tables with the
//! expected shape (headers present, rectangular rows, a minimum row
//! count). That turns "the bench printed something" into a structural
//! guarantee the uploaded perf trajectory can be diffed against.

use crate::error::{EakmError, Result};
use crate::json::Json;

/// Expected shape of one [`TextTable::to_json`](crate::bench_support::TextTable::to_json)
/// table inside a bench JSON document.
#[derive(Clone, Debug)]
pub struct TableSpec {
    /// Top-level key the table sits under (e.g. `"scaling"`).
    pub key: String,
    /// Minimum number of data rows the table must carry.
    pub min_rows: usize,
}

impl TableSpec {
    /// Parse a `key:min_rows` argument (as passed to `bench_check`).
    pub fn parse(arg: &str) -> Result<TableSpec> {
        let (key, rows) = arg.split_once(':').ok_or_else(|| {
            EakmError::Config(format!("expected table spec key:min_rows, got {arg:?}"))
        })?;
        let min_rows = rows
            .parse::<usize>()
            .map_err(|_| EakmError::Config(format!("bad min_rows in table spec {arg:?}")))?;
        Ok(TableSpec {
            key: key.to_string(),
            min_rows,
        })
    }
}

/// Validate one bench JSON document: it must identify itself as
/// `bench_name` under the `"bench"` key and contain every table in
/// `tables` with headers, rectangular rows, and at least `min_rows`
/// rows. Returns a one-line summary for CI logs.
pub fn check_bench_json(text: &str, bench_name: &str, tables: &[TableSpec]) -> Result<String> {
    let doc = Json::parse(text)?;
    let fail = |what: String| EakmError::Data(format!("bench json: {what}"));
    match doc.get("bench").and_then(Json::as_str) {
        Some(b) if b == bench_name => {}
        Some(b) => return Err(fail(format!("bench is {b:?}, expected {bench_name:?}"))),
        None => return Err(fail("missing \"bench\" identifier".into())),
    }
    let mut summary = format!("{bench_name}: ok");
    for spec in tables {
        let table = doc
            .get(&spec.key)
            .ok_or_else(|| fail(format!("missing table {:?}", spec.key)))?;
        if table.get("title").and_then(Json::as_str).is_none() {
            return Err(fail(format!("table {:?} has no title", spec.key)));
        }
        let headers = table
            .get("headers")
            .and_then(Json::as_arr)
            .ok_or_else(|| fail(format!("table {:?} has no headers", spec.key)))?;
        if headers.is_empty() || headers.iter().any(|h| h.as_str().is_none()) {
            return Err(fail(format!("table {:?} headers malformed", spec.key)));
        }
        let rows = table
            .get("rows")
            .and_then(Json::as_arr)
            .ok_or_else(|| fail(format!("table {:?} has no rows", spec.key)))?;
        if rows.len() < spec.min_rows {
            return Err(fail(format!(
                "table {:?} has {} rows, expected ≥ {}",
                spec.key,
                rows.len(),
                spec.min_rows
            )));
        }
        for (i, row) in rows.iter().enumerate() {
            let cells = row
                .as_arr()
                .ok_or_else(|| fail(format!("table {:?} row {i} is not an array", spec.key)))?;
            if cells.len() != headers.len() {
                return Err(fail(format!(
                    "table {:?} row {i} has {} cells for {} headers",
                    spec.key,
                    cells.len(),
                    headers.len()
                )));
            }
        }
        summary.push_str(&format!(" {}[{}]", spec.key, rows.len()));
    }
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_support::TextTable;

    fn doc() -> String {
        let mut t = TextTable::new("T").headers(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["3".into(), "4".into()]);
        Json::obj()
            .field("bench", "demo")
            .field("scaling", t.to_json())
            .to_string()
    }

    #[test]
    fn accepts_a_well_formed_document() {
        let spec = [TableSpec::parse("scaling:2").unwrap()];
        let summary = check_bench_json(&doc(), "demo", &spec).unwrap();
        assert!(summary.contains("scaling[2]"), "{summary}");
    }

    #[test]
    fn rejects_wrong_bench_missing_table_and_short_tables() {
        let spec = [TableSpec::parse("scaling:2").unwrap()];
        assert!(check_bench_json(&doc(), "other", &spec).is_err());
        let missing = [TableSpec::parse("nope:1").unwrap()];
        assert!(check_bench_json(&doc(), "demo", &missing).is_err());
        let short = [TableSpec::parse("scaling:9").unwrap()];
        assert!(check_bench_json(&doc(), "demo", &short).is_err());
        assert!(check_bench_json("not json", "demo", &spec).is_err());
    }

    #[test]
    fn rejects_ragged_rows() {
        let ragged = r#"{"bench":"demo","t":{"title":"T","headers":["a","b"],"rows":[["1"]]}}"#;
        let spec = [TableSpec::parse("t:1").unwrap()];
        assert!(check_bench_json(ragged, "demo", &spec).is_err());
    }

    #[test]
    fn table_spec_parsing() {
        let spec = TableSpec::parse("dispatch:3").unwrap();
        assert_eq!(spec.key, "dispatch");
        assert_eq!(spec.min_rows, 3);
        assert!(TableSpec::parse("nope").is_err());
        assert!(TableSpec::parse("x:abc").is_err());
    }
}
