//! Schema checks for the machine-readable `BENCH_*.json` companions the
//! bench harnesses emit next to their text tables.
//!
//! CI's `bench-smoke` job runs the harnesses at a tiny scale and then
//! asserts — through the `bench_check` binary, which is a thin argv
//! wrapper over [`check_bench_json`] — that each JSON artifact parses,
//! identifies the right bench, and contains its tables with the
//! expected shape (headers present, rectangular rows, a minimum row
//! count). That turns "the bench printed something" into a structural
//! guarantee the uploaded perf trajectory can be diffed against.

use crate::error::{EakmError, Result};
use crate::json::Json;

/// Expected shape of one [`TextTable::to_json`](crate::bench_support::TextTable::to_json)
/// table inside a bench JSON document.
#[derive(Clone, Debug)]
pub struct TableSpec {
    /// Top-level key the table sits under (e.g. `"scaling"`).
    pub key: String,
    /// Minimum number of data rows the table must carry.
    pub min_rows: usize,
}

impl TableSpec {
    /// Parse a `key:min_rows` argument (as passed to `bench_check`).
    pub fn parse(arg: &str) -> Result<TableSpec> {
        let (key, rows) = arg.split_once(':').ok_or_else(|| {
            EakmError::Config(format!("expected table spec key:min_rows, got {arg:?}"))
        })?;
        let min_rows = rows
            .parse::<usize>()
            .map_err(|_| EakmError::Config(format!("bad min_rows in table spec {arg:?}")))?;
        Ok(TableSpec {
            key: key.to_string(),
            min_rows,
        })
    }
}

/// Validate one bench JSON document: it must identify itself as
/// `bench_name` under the `"bench"` key and contain every table in
/// `tables` with headers, rectangular rows, and at least `min_rows`
/// rows. Returns a one-line summary for CI logs.
pub fn check_bench_json(text: &str, bench_name: &str, tables: &[TableSpec]) -> Result<String> {
    let doc = Json::parse(text)?;
    let fail = |what: String| EakmError::Data(format!("bench json: {what}"));
    match doc.get("bench").and_then(Json::as_str) {
        Some(b) if b == bench_name => {}
        Some(b) => return Err(fail(format!("bench is {b:?}, expected {bench_name:?}"))),
        None => return Err(fail("missing \"bench\" identifier".into())),
    }
    let mut summary = format!("{bench_name}: ok");
    for spec in tables {
        let table = doc
            .get(&spec.key)
            .ok_or_else(|| fail(format!("missing table {:?}", spec.key)))?;
        if table.get("title").and_then(Json::as_str).is_none() {
            return Err(fail(format!("table {:?} has no title", spec.key)));
        }
        let headers = table
            .get("headers")
            .and_then(Json::as_arr)
            .ok_or_else(|| fail(format!("table {:?} has no headers", spec.key)))?;
        if headers.is_empty() || headers.iter().any(|h| h.as_str().is_none()) {
            return Err(fail(format!("table {:?} headers malformed", spec.key)));
        }
        let rows = table
            .get("rows")
            .and_then(Json::as_arr)
            .ok_or_else(|| fail(format!("table {:?} has no rows", spec.key)))?;
        if rows.len() < spec.min_rows {
            return Err(fail(format!(
                "table {:?} has {} rows, expected ≥ {}",
                spec.key,
                rows.len(),
                spec.min_rows
            )));
        }
        for (i, row) in rows.iter().enumerate() {
            let cells = row
                .as_arr()
                .ok_or_else(|| fail(format!("table {:?} row {i} is not an array", spec.key)))?;
            if cells.len() != headers.len() {
                return Err(fail(format!(
                    "table {:?} row {i} has {} cells for {} headers",
                    spec.key,
                    cells.len(),
                    headers.len()
                )));
            }
        }
        summary.push_str(&format!(" {}[{}]", spec.key, rows.len()));
    }
    Ok(summary)
}

/// One row-level regression found by [`diff_bench_json`] — a wall-time
/// cell that grew past the threshold, or a throughput cell that fell
/// below the baseline floor.
#[derive(Clone, Debug)]
pub struct DiffRegression {
    /// Table key + row label + column header, for the CI log.
    pub what: String,
    /// Old cell value (wall seconds, or GB/s / GFLOP/s / rows/s).
    pub old: f64,
    /// New cell value, same unit as `old`.
    pub new: f64,
}

/// True when a column holds wall-time cells (gated: higher is worse).
fn is_timing_header(h: &str) -> bool {
    h.contains("[s]") || h.contains("secs") || h.contains("[µs")
}

/// True when a column holds throughput cells (gated: *lower* is worse).
/// Committed baselines put conservative floors here, so the gate only
/// fires on order-of-magnitude collapses, not run-to-run jitter.
fn is_throughput_header(h: &str) -> bool {
    h.contains("GB/s") || h.contains("GFLOP/s") || h.contains("rows/s")
}

/// Row key: every cell that is neither a gated (timing/throughput)
/// column nor float-formatted (ratios, speedups, and wall cells carry a
/// '.'; labels, integer knobs like k/T, and booleans do not). Stable
/// across runs of the same bench configuration — throughput columns are
/// excluded by header, not by format, because their "-" markers would
/// otherwise leak into the key.
fn row_key(headers: &[String], cells: &[String]) -> String {
    let mut key = String::new();
    for (h, c) in headers.iter().zip(cells) {
        if is_timing_header(h) || is_throughput_header(h) || c.contains('.') {
            continue;
        }
        key.push_str(c);
        key.push('\u{1f}');
    }
    key
}

fn tables_of(doc: &Json) -> Vec<(String, &Json)> {
    let Json::Obj(fields) = doc else {
        return Vec::new();
    };
    fields
        .iter()
        .filter(|(_, v)| v.get("headers").is_some() && v.get("rows").is_some())
        .map(|(k, v)| (k.clone(), v))
        .collect()
}

fn str_cells(row: &Json) -> Option<Vec<String>> {
    row.as_arr().map(|cells| {
        cells
            .iter()
            .map(|c| c.as_str().unwrap_or_default().to_string())
            .collect()
    })
}

/// Compare two `BENCH_*.json` artifacts row by row and report per-row
/// deltas for every gated cell. Rows are matched within same-keyed
/// tables by their non-gated, non-float cells (dataset, algorithm, k,
/// T, …). Returns `(report_lines, regressions)`:
///
/// * a **timing** cell regresses when `new > old × (1 + threshold)`
///   **and** both sides are at least `min_wall` seconds (micro rows are
///   pure noise);
/// * a **throughput** cell (GB/s, GFLOP/s, rows/s) regresses when
///   `old > new × (1 + threshold)` — the committed baseline is a
///   *floor*, so only a drop below it gates; there is no `min_wall`
///   analogue because a floor is already an absolute value.
///
/// Rows present on only one side are reported but never gate.
pub fn diff_bench_json(
    old_text: &str,
    new_text: &str,
    threshold: f64,
    min_wall: f64,
) -> Result<(Vec<String>, Vec<DiffRegression>)> {
    let old_doc = Json::parse(old_text)?;
    let new_doc = Json::parse(new_text)?;
    let mut lines = Vec::new();
    let mut regressions = Vec::new();

    let old_tables = tables_of(&old_doc);
    for (key, new_table) in tables_of(&new_doc) {
        let Some((_, old_table)) = old_tables.iter().find(|(k, _)| *k == key) else {
            lines.push(format!("{key}: table only in new artifact — skipped"));
            continue;
        };
        let headers: Vec<String> = new_table
            .get("headers")
            .and_then(Json::as_arr)
            .map(|hs| {
                hs.iter()
                    .map(|h| h.as_str().unwrap_or_default().to_string())
                    .collect()
            })
            .unwrap_or_default();
        let old_headers: Vec<String> = old_table
            .get("headers")
            .and_then(Json::as_arr)
            .map(|hs| {
                hs.iter()
                    .map(|h| h.as_str().unwrap_or_default().to_string())
                    .collect()
            })
            .unwrap_or_default();
        if headers != old_headers {
            lines.push(format!("{key}: headers changed — skipped"));
            continue;
        }
        let empty = Vec::new();
        let old_rows = old_table.get("rows").and_then(Json::as_arr).unwrap_or(&empty);
        let new_rows = new_table.get("rows").and_then(Json::as_arr).unwrap_or(&empty);
        for new_row in new_rows {
            let Some(new_cells) = str_cells(new_row) else {
                continue;
            };
            // ragged rows (a hand-edited baseline never passes the
            // schema gate) must degrade to a report line, not a panic
            if new_cells.len() != headers.len() {
                lines.push(format!("{key}: malformed new row — skipped"));
                continue;
            }
            let key_cells = row_key(&headers, &new_cells);
            let old_cells = old_rows
                .iter()
                .filter_map(str_cells)
                .filter(|c| c.len() == headers.len())
                .find(|c| row_key(&headers, c) == key_cells);
            let Some(old_cells) = old_cells else {
                lines.push(format!("{key}: new row [{}]", new_cells.join(" ")));
                continue;
            };
            for (c, h) in headers.iter().enumerate() {
                let timing = is_timing_header(h);
                let throughput = is_throughput_header(h);
                if !timing && !throughput {
                    continue;
                }
                let (Ok(old), Ok(new)) = (
                    old_cells[c].parse::<f64>(),
                    new_cells[c].parse::<f64>(),
                ) else {
                    continue; // "-" markers pass through
                };
                let delta = if old > 0.0 { new / old - 1.0 } else { 0.0 };
                let what = format!("{key} [{}] {h}", new_cells.join(" "));
                if timing {
                    lines.push(format!(
                        "{what}: {old:.4}s → {new:.4}s ({delta:+.1}%)",
                        delta = delta * 100.0
                    ));
                    if new > old * (1.0 + threshold) && old >= min_wall && new >= min_wall {
                        regressions.push(DiffRegression { what, old, new });
                    }
                } else {
                    lines.push(format!(
                        "{what}: {old:.3} → {new:.3} ({delta:+.1}%)",
                        delta = delta * 100.0
                    ));
                    if old > new * (1.0 + threshold) {
                        regressions.push(DiffRegression { what, old, new });
                    }
                }
            }
        }
    }
    Ok((lines, regressions))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_support::TextTable;

    fn doc() -> String {
        let mut t = TextTable::new("T").headers(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["3".into(), "4".into()]);
        Json::obj()
            .field("bench", "demo")
            .field("scaling", t.to_json())
            .to_string()
    }

    #[test]
    fn accepts_a_well_formed_document() {
        let spec = [TableSpec::parse("scaling:2").unwrap()];
        let summary = check_bench_json(&doc(), "demo", &spec).unwrap();
        assert!(summary.contains("scaling[2]"), "{summary}");
    }

    #[test]
    fn rejects_wrong_bench_missing_table_and_short_tables() {
        let spec = [TableSpec::parse("scaling:2").unwrap()];
        assert!(check_bench_json(&doc(), "other", &spec).is_err());
        let missing = [TableSpec::parse("nope:1").unwrap()];
        assert!(check_bench_json(&doc(), "demo", &missing).is_err());
        let short = [TableSpec::parse("scaling:9").unwrap()];
        assert!(check_bench_json(&doc(), "demo", &short).is_err());
        assert!(check_bench_json("not json", "demo", &spec).is_err());
    }

    #[test]
    fn rejects_ragged_rows() {
        let ragged = r#"{"bench":"demo","t":{"title":"T","headers":["a","b"],"rows":[["1"]]}}"#;
        let spec = [TableSpec::parse("t:1").unwrap()];
        assert!(check_bench_json(ragged, "demo", &spec).is_err());
    }

    fn timing_doc(walls: &[(&str, &str)]) -> String {
        let mut t = TextTable::new("T").headers(&["ds", "T", "wall[s]", "speedup"]);
        for (ds, wall) in walls {
            t.row(vec![ds.to_string(), "2".into(), wall.to_string(), "1.00".into()]);
        }
        Json::obj()
            .field("bench", "demo")
            .field("scaling", t.to_json())
            .to_string()
    }

    #[test]
    fn diff_reports_deltas_and_flags_regressions() {
        let old = timing_doc(&[("birch", "0.5000"), ("europe", "1.0000")]);
        let new = timing_doc(&[("birch", "0.5200"), ("europe", "2.5000")]);
        let (lines, regressions) = diff_bench_json(&old, &new, 0.5, 0.05).unwrap();
        // every matched timing cell produces a report line
        assert!(lines.iter().any(|l| l.contains("birch") && l.contains("+4.0%")), "{lines:?}");
        // only europe breaches the +50% threshold
        assert_eq!(regressions.len(), 1, "{regressions:?}");
        assert!(regressions[0].what.contains("europe"));
        assert_eq!(regressions[0].old, 1.0);
        assert_eq!(regressions[0].new, 2.5);
    }

    #[test]
    fn diff_ignores_micro_rows_and_unmatched_rows() {
        let old = timing_doc(&[("tiny", "0.0010")]);
        // 10× slower but below min_wall on the old side → noise, not a gate
        let new = timing_doc(&[("tiny", "0.0100"), ("fresh", "9.0000")]);
        let (lines, regressions) = diff_bench_json(&old, &new, 0.5, 0.05).unwrap();
        assert!(regressions.is_empty(), "{regressions:?}");
        assert!(lines.iter().any(|l| l.contains("new row") && l.contains("fresh")));
    }

    #[test]
    fn diff_keys_rows_by_non_timing_cells() {
        // same dataset at two thread counts must not collide: T is an
        // integer cell and therefore part of the key
        let mut t_old = TextTable::new("T").headers(&["ds", "T", "wall[s]"]);
        t_old.row(vec!["birch".into(), "1".into(), "1.0000".into()]);
        t_old.row(vec!["birch".into(), "4".into(), "0.3000".into()]);
        let old = Json::obj().field("bench", "demo").field("s", t_old.to_json()).to_string();
        let mut t_new = TextTable::new("T").headers(&["ds", "T", "wall[s]"]);
        t_new.row(vec!["birch".into(), "1".into(), "1.0100".into()]);
        t_new.row(vec!["birch".into(), "4".into(), "0.9000".into()]);
        let new = Json::obj().field("bench", "demo").field("s", t_new.to_json()).to_string();
        let (_, regressions) = diff_bench_json(&old, &new, 0.5, 0.05).unwrap();
        assert_eq!(regressions.len(), 1);
        assert!(regressions[0].what.contains('4'), "{:?}", regressions[0]);
    }

    #[test]
    fn diff_rejects_garbage_input() {
        assert!(diff_bench_json("not json", "{}", 0.5, 0.05).is_err());
        assert!(diff_bench_json("{}", "not json", 0.5, 0.05).is_err());
        // no tables at all: empty report, no regressions
        let (lines, regs) = diff_bench_json("{}", "{}", 0.5, 0.05).unwrap();
        assert!(lines.is_empty() && regs.is_empty());
        // ragged rows (e.g. a hand-edited baseline) degrade to a skip
        // line instead of an out-of-bounds panic
        let ragged = r#"{"t":{"title":"T","headers":["a","wall[s]"],"rows":[["x"]]}}"#;
        let (lines, regs) = diff_bench_json(ragged, ragged, 0.5, 0.05).unwrap();
        assert!(lines.iter().any(|l| l.contains("malformed")), "{lines:?}");
        assert!(regs.is_empty());
    }

    fn throughput_doc(rows: &[(&str, &str, &str)]) -> String {
        let mut t = TextTable::new("T").headers(&["kernel", "median[ms]", "GB/s"]);
        for (kernel, ms, gbs) in rows {
            t.row(vec![kernel.to_string(), ms.to_string(), gbs.to_string()]);
        }
        Json::obj()
            .field("bench", "micro")
            .field("kernels", t.to_json())
            .to_string()
    }

    #[test]
    fn diff_gates_throughput_drops_below_the_floor() {
        // baseline floor 0.10 GB/s, threshold 9.0 → gate iff new < 0.01
        let old = throughput_doc(&[("sqdist d=32", "1.000", "0.10")]);
        let slow = throughput_doc(&[("sqdist d=32", "900.000", "0.005")]);
        let (lines, regressions) = diff_bench_json(&old, &slow, 9.0, 0.05).unwrap();
        assert_eq!(regressions.len(), 1, "{regressions:?}");
        assert!(regressions[0].what.contains("GB/s"), "{:?}", regressions[0]);
        // median[ms] is deliberately NOT a timing header: no [s]/secs/µs
        assert!(
            !lines.iter().any(|l| l.contains("median")),
            "median column must not be diffed: {lines:?}"
        );
    }

    #[test]
    fn diff_never_gates_throughput_increases() {
        let old = throughput_doc(&[("sqdist d=32", "1.000", "0.10")]);
        let fast = throughput_doc(&[("sqdist d=32", "0.010", "25.000")]);
        let (lines, regressions) = diff_bench_json(&old, &fast, 9.0, 0.05).unwrap();
        assert!(regressions.is_empty(), "{regressions:?}");
        assert!(lines.iter().any(|l| l.contains("GB/s")), "{lines:?}");
    }

    #[test]
    fn diff_passes_dash_throughput_cells_and_keys_rows_by_label() {
        // "-" cells never parse → never gate; and since throughput
        // columns are excluded from the row key by header, the rows
        // still match across artifacts
        let old = throughput_doc(&[("exp-ns round k=64", "5.000", "-")]);
        let new = throughput_doc(&[("exp-ns round k=64", "5.100", "-")]);
        let (lines, regressions) = diff_bench_json(&old, &new, 9.0, 0.05).unwrap();
        assert!(regressions.is_empty(), "{regressions:?}");
        assert!(
            !lines.iter().any(|l| l.contains("new row")),
            "dash rows must still key-match: {lines:?}"
        );
    }

    #[test]
    fn table_spec_parsing() {
        let spec = TableSpec::parse("dispatch:3").unwrap();
        assert_eq!(spec.key, "dispatch");
        assert_eq!(spec.min_rows, 3);
        assert!(TableSpec::parse("nope").is_err());
        assert!(TableSpec::parse("x:abc").is_err());
    }
}
