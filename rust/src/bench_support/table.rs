//! Fixed-width text table rendering matching the paper's table style,
//! plus a machine-readable JSON form for CI regression diffing.

use crate::json::Json;

/// A simple text table builder.
#[derive(Clone, Debug, Default)]
pub struct TextTable {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    /// Start a table with a caption.
    pub fn new(title: impl Into<String>) -> Self {
        TextTable {
            title: title.into(),
            ..Default::default()
        }
    }

    /// Set column headers.
    pub fn headers(mut self, headers: &[&str]) -> Self {
        self.headers = headers.iter().map(|s| s.to_string()).collect();
        self
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    /// Format a ratio the way the paper prints them (2 decimals, the
    /// timeout/memory markers pass through).
    pub fn fmt_ratio(x: f64) -> String {
        if x.is_nan() {
            "-".to_string()
        } else {
            format!("{x:.2}")
        }
    }

    /// The table as machine-readable JSON
    /// (`{"title", "headers", "rows"}`, every cell the exact rendered
    /// string) — benches write this next to the text table so perf
    /// regressions are diffable in CI without parsing the fixed-width
    /// layout.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("title", self.title.as_str())
            .field(
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::from(h.as_str())).collect()),
            )
            .field(
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|row| {
                            Json::Arr(row.iter().map(|c| Json::from(c.as_str())).collect())
                        })
                        .collect(),
                ),
            )
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let cols = self
            .headers
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; cols];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&self.title);
        out.push('\n');
        if !self.headers.is_empty() {
            for (i, h) in self.headers.iter().enumerate() {
                out.push_str(&format!("{:>w$}  ", h, w = widths[i]));
            }
            out.push('\n');
            let total: usize = widths.iter().sum::<usize>() + 2 * cols;
            out.push_str(&"-".repeat(total));
            out.push('\n');
        }
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                out.push_str(&format!("{:>w$}  ", cell, w = widths[i]));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new("Table X").headers(&["ds", "qt"]);
        t.row(vec!["birch".into(), "0.48".into()]);
        t.row(vec!["i".into(), "12.00".into()]);
        let s = t.render();
        assert!(s.starts_with("Table X\n"));
        assert!(s.contains("birch"));
        // each data line has aligned columns (same length)
        let lines: Vec<&str> = s.lines().skip(3).collect();
        assert_eq!(lines[0].len(), lines[1].len());
    }

    #[test]
    fn ratio_formatting() {
        assert_eq!(TextTable::fmt_ratio(0.5), "0.50");
        assert_eq!(TextTable::fmt_ratio(f64::NAN), "-");
    }

    #[test]
    fn json_mirrors_the_table() {
        let mut t = TextTable::new("Table X").headers(&["ds", "qt"]);
        t.row(vec!["birch".into(), "0.48".into()]);
        let j = t.to_json();
        assert_eq!(
            j.to_string(),
            r#"{"title":"Table X","headers":["ds","qt"],"rows":[["birch","0.48"]]}"#
        );
        // and it parses back
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("title").unwrap().as_str(), Some("Table X"));
    }
}
