//! Repeated-run measurement: mean wall time, distance counts, and
//! iteration statistics over seeds — the quantities the paper's tables
//! are built from (`q_t`, `q_a`, `q_au`).

use std::time::Duration;

use crate::algorithms::Algorithm;
use crate::config::RunConfig;
use crate::coordinator::Runner;
use crate::data::Dataset;

/// Aggregated statistics over seeds for one (dataset, algorithm, k).
#[derive(Clone, Debug)]
pub struct MeasureStats {
    /// Algorithm measured.
    pub algorithm: Algorithm,
    /// Mean wall time.
    pub mean_wall: Duration,
    /// Std-dev of wall time.
    pub sd_wall: Duration,
    /// Mean assignment-step distance calculations (paper `a`).
    pub mean_qa: f64,
    /// Mean total distance calculations (paper `au`).
    pub mean_qau: f64,
    /// Mean iterations to convergence.
    pub mean_iters: f64,
    /// Std-dev of iterations.
    pub sd_iters: f64,
    /// Mean final objective (all algorithms must agree — checked).
    pub mean_mse: f64,
    /// Number of seeds.
    pub seeds: usize,
}

/// Run `alg` on `data` for seeds `0..seeds`, averaging.
pub fn measure(
    data: &Dataset,
    alg: Algorithm,
    k: usize,
    seeds: usize,
    threads: usize,
) -> MeasureStats {
    measure_capped(data, alg, k, seeds, threads, 100_000)
}

/// As [`measure`] but with a round cap. Because every algorithm is
/// *exact*, capping rounds keeps cross-algorithm ratios valid (they all
/// execute the identical round sequence) while bounding bench time on
/// slow-converging workloads (the paper's urand datasets run thousands
/// of rounds).
pub fn measure_capped(
    data: &Dataset,
    alg: Algorithm,
    k: usize,
    seeds: usize,
    threads: usize,
    max_iters: usize,
) -> MeasureStats {
    let mut walls = Vec::with_capacity(seeds);
    let mut qa = 0.0;
    let mut qau = 0.0;
    let mut iters = Vec::with_capacity(seeds);
    let mut mse = 0.0;
    for seed in 0..seeds {
        let cfg = RunConfig::new(alg, k)
            .seed(seed as u64)
            .threads(threads)
            .max_iters(max_iters);
        let out = Runner::new(&cfg).run(data).expect("run failed");
        walls.push(out.wall);
        qa += out.counters.assignment as f64;
        qau += out.counters.total() as f64;
        iters.push(out.iterations as f64);
        mse += out.mse;
    }
    let n = seeds as f64;
    let mean_wall_s = walls.iter().map(|w| w.as_secs_f64()).sum::<f64>() / n;
    let var_wall = walls
        .iter()
        .map(|w| (w.as_secs_f64() - mean_wall_s).powi(2))
        .sum::<f64>()
        / n;
    let mean_iters = iters.iter().sum::<f64>() / n;
    let var_iters = iters.iter().map(|x| (x - mean_iters).powi(2)).sum::<f64>() / n;
    MeasureStats {
        algorithm: alg,
        mean_wall: Duration::from_secs_f64(mean_wall_s),
        sd_wall: Duration::from_secs_f64(var_wall.sqrt()),
        mean_qa: qa / n,
        mean_qau: qau / n,
        mean_iters,
        sd_iters: var_iters.sqrt(),
        mean_mse: mse / n,
        seeds,
    }
}

/// Ratio of two durations as f64 (`a / b`).
pub fn ratio(a: Duration, b: Duration) -> f64 {
    a.as_secs_f64() / b.as_secs_f64().max(1e-12)
}

/// Median of a slice (not-NaN assumed).
pub fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len();
    if n == 0 {
        return f64::NAN;
    }
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        0.5 * (xs[n / 2 - 1] + xs[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;

    #[test]
    fn measure_aggregates_over_seeds() {
        let ds = blobs(300, 3, 4, 0.1, 2);
        let st = measure(&ds, Algorithm::Sta, 4, 2, 1);
        assert_eq!(st.seeds, 2);
        assert!(st.mean_qa > 0.0);
        assert!(st.mean_qau >= st.mean_qa);
        assert!(st.mean_iters >= 1.0);
        assert!(st.mean_mse.is_finite());
    }

    #[test]
    fn median_basics() {
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&mut [4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!(median(&mut []).is_nan());
    }

    #[test]
    fn ratio_guards_zero() {
        assert!(ratio(Duration::from_secs(1), Duration::from_secs(0)) > 0.0);
    }
}
