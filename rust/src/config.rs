//! Run and experiment configuration.
//!
//! [`RunConfig`] is the programmatic builder used by the library API and
//! the CLI; it can also be parsed from a simple `key = value` config file
//! (a TOML subset — see [`RunConfig::from_str_cfg`]) so experiment grids
//! are scriptable without external dependencies.

use std::time::Duration;

use crate::algorithms::Algorithm;
use crate::error::{EakmError, Result};
use crate::init::InitMethod;

/// Configuration for a single clustering run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Which algorithm to run (paper notation; `Auto` resolves by d).
    pub algorithm: Algorithm,
    /// Number of clusters.
    pub k: usize,
    /// RNG seed for centroid initialisation.
    pub seed: u64,
    /// Worker threads for the whole round (scan + update + centroid
    /// builds). `AUTO_THREADS` (0) resolves to the machine's available
    /// parallelism at engine construction.
    pub threads: usize,
    /// Seeding strategy.
    pub init: InitMethod,
    /// Hard cap on Lloyd rounds.
    pub max_iters: usize,
    /// Optional wall-clock limit (paper: 40 min per run).
    pub time_limit: Option<Duration>,
    /// Byte budget for the ns centroid history (paper: 4 GB total memory).
    pub history_budget: usize,
    /// Override the ns reset period (testing; `None` = paper formula).
    pub history_cap: Option<usize>,
    /// Record per-round wall times in the report.
    pub record_rounds: bool,
    /// Mini-batch mode: rows sampled per round (`None` = exact
    /// full-batch engine). Values ≥ n run the exact engine unchanged;
    /// values < k are clamped up to k (a batch must seat every cluster).
    pub batch_size: Option<usize>,
    /// Mini-batch growth factor per round: > 1 grows a *nested* batch
    /// (old batch ⊂ new batch, Newling & Fleuret 2016b) until it covers
    /// the dataset; exactly 1 redraws a fresh batch every round
    /// (Sculley-style resampling). Ignored without `batch_size`.
    pub batch_growth: f64,
    /// Shards in the over-decomposed scan plan.
    /// [`AUTO_SCAN_SHARDS`](crate::coordinator::sched::AUTO_SCAN_SHARDS)
    /// (0) derives the count from `n`; explicit counts are clamped by
    /// the plan's
    /// [`MIN_SHARD_ROWS`](crate::coordinator::sched::MIN_SHARD_ROWS)
    /// floor. Results are bit-identical at any value — this is a
    /// scheduling knob, not a math knob.
    pub scan_shards: usize,
}

/// Sentinel thread count: resolve from `available_parallelism`
/// (the same sentinel as [`runtime::rt::AUTO`](crate::runtime::rt::AUTO)).
pub const AUTO_THREADS: usize = crate::runtime::rt::AUTO;

impl RunConfig {
    /// A config with the paper's defaults.
    pub fn new(algorithm: Algorithm, k: usize) -> Self {
        RunConfig {
            algorithm,
            k,
            seed: 0,
            threads: 1,
            init: InitMethod::Random,
            max_iters: 10_000,
            time_limit: None,
            history_budget: 1 << 30, // 1 GB
            history_cap: None,
            record_rounds: false,
            batch_size: None,
            batch_growth: 2.0, // nested doubling, the 2016b default
            scan_shards: crate::coordinator::sched::AUTO_SCAN_SHARDS,
        }
    }

    /// Set the seed (builder style).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Set the thread count (builder style). [`AUTO_THREADS`] (0)
    /// resolves to the machine's available parallelism.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// The effective worker count: `threads`, or the machine's available
    /// parallelism when set to [`AUTO_THREADS`].
    pub fn resolved_threads(&self) -> usize {
        crate::runtime::rt::resolve_threads(self.threads)
    }

    /// Set the iteration cap (builder style).
    pub fn max_iters(mut self, max_iters: usize) -> Self {
        self.max_iters = max_iters;
        self
    }

    /// Set the seeding method (builder style).
    pub fn init(mut self, init: InitMethod) -> Self {
        self.init = init;
        self
    }

    /// Set a wall-clock limit (builder style).
    pub fn time_limit(mut self, limit: Duration) -> Self {
        self.time_limit = Some(limit);
        self
    }

    /// Enable mini-batch rounds of (initially) `batch_size` sampled
    /// rows (builder style). Sizes ≥ n run the exact full-batch engine.
    pub fn batch_size(mut self, batch_size: usize) -> Self {
        self.batch_size = Some(batch_size);
        self
    }

    /// Set the mini-batch growth factor (builder style): > 1 nests
    /// (doubling = 2.0), exactly 1 redraws fresh batches.
    pub fn batch_growth(mut self, batch_growth: f64) -> Self {
        self.batch_growth = batch_growth;
        self
    }

    /// Set the scan-plan shard count (builder style).
    /// [`AUTO_SCAN_SHARDS`](crate::coordinator::sched::AUTO_SCAN_SHARDS)
    /// (0) derives it from `n`.
    pub fn scan_shards(mut self, scan_shards: usize) -> Self {
        self.scan_shards = scan_shards;
        self
    }

    /// Validate against a dataset size.
    pub fn validate(&self, n: usize) -> Result<()> {
        if self.k == 0 {
            return Err(EakmError::Config("k must be positive".into()));
        }
        if self.k > n {
            return Err(EakmError::Config(format!("k={} exceeds n={n}", self.k)));
        }
        if self.max_iters == 0 {
            return Err(EakmError::Config("max_iters must be positive".into()));
        }
        if self.batch_size == Some(0) {
            return Err(EakmError::Config("batch_size must be ≥ 1".into()));
        }
        if !(self.batch_growth.is_finite() && self.batch_growth >= 1.0) {
            return Err(EakmError::Config(format!(
                "batch_growth must be a finite factor ≥ 1, got {}",
                self.batch_growth
            )));
        }
        Ok(())
    }

    /// Parse a minimal `key = value` config text (TOML subset: one pair
    /// per line, `#` comments, unquoted scalars, an optional `[run]`
    /// section header). Unknown keys *and unknown sections* error so
    /// typos surface — a misspelt section used to be silently skipped,
    /// hiding every key under it from validation.
    pub fn from_str_cfg(text: &str) -> Result<Self> {
        let mut cfg = RunConfig::new(Algorithm::ExpNs, 100);
        for (no, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line.starts_with('[') {
                match line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                    Some(section) if section.trim() == "run" => continue,
                    Some(section) => {
                        return Err(EakmError::Config(format!(
                            "line {}: unknown section [{}] — only [run] is recognised",
                            no + 1,
                            section.trim()
                        )))
                    }
                    None => {
                        return Err(EakmError::Config(format!(
                            "line {}: malformed section header {line:?}",
                            no + 1
                        )))
                    }
                }
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| EakmError::Config(format!("line {}: expected key = value", no + 1)))?;
            let (key, value) = (key.trim(), value.trim().trim_matches('"'));
            match key {
                "algorithm" => {
                    cfg.algorithm = Algorithm::parse(value)
                        .ok_or_else(|| EakmError::Config(format!("unknown algorithm {value:?}")))?;
                }
                "k" => cfg.k = parse_num(key, value)?,
                "seed" => cfg.seed = parse_num::<u64>(key, value)?,
                "threads" => {
                    cfg.threads = if value == "auto" {
                        AUTO_THREADS
                    } else {
                        let n = parse_num::<usize>(key, value)?;
                        if n == 0 {
                            return Err(EakmError::Config(
                                "threads must be ≥ 1, or \"auto\"".into(),
                            ));
                        }
                        n
                    };
                }
                "init" => {
                    cfg.init = InitMethod::parse(value)
                        .ok_or_else(|| EakmError::Config(format!("unknown init {value:?}")))?;
                }
                "max_iters" => cfg.max_iters = parse_num(key, value)?,
                "batch_size" => {
                    let b: usize = parse_num(key, value)?;
                    if b == 0 {
                        return Err(EakmError::Config("batch_size must be ≥ 1".into()));
                    }
                    cfg.batch_size = Some(b);
                }
                "batch_growth" => cfg.batch_growth = parse_num(key, value)?,
                "scan_shards" => {
                    cfg.scan_shards = if value == "auto" {
                        crate::coordinator::sched::AUTO_SCAN_SHARDS
                    } else {
                        let n = parse_num::<usize>(key, value)?;
                        if n == 0 {
                            return Err(EakmError::Config(
                                "scan_shards must be ≥ 1, or \"auto\"".into(),
                            ));
                        }
                        n
                    };
                }
                "time_limit_secs" => {
                    cfg.time_limit = Some(Duration::from_secs(parse_num(key, value)?));
                }
                "history_budget" => cfg.history_budget = parse_num(key, value)?,
                "history_cap" => cfg.history_cap = Some(parse_num(key, value)?),
                "record_rounds" => cfg.record_rounds = value == "true",
                _ => return Err(EakmError::Config(format!("unknown key {key:?}"))),
            }
        }
        Ok(cfg)
    }
}

fn parse_num<T: std::str::FromStr>(key: &str, value: &str) -> Result<T> {
    value
        .parse::<T>()
        .map_err(|_| EakmError::Config(format!("bad value for {key}: {value:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains() {
        let cfg = RunConfig::new(Algorithm::Exp, 50)
            .seed(9)
            .threads(4)
            .max_iters(10);
        assert_eq!(cfg.k, 50);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.max_iters, 10);
    }

    #[test]
    fn validate_rejects_bad_k() {
        assert!(RunConfig::new(Algorithm::Sta, 0).validate(10).is_err());
        assert!(RunConfig::new(Algorithm::Sta, 11).validate(10).is_err());
        assert!(RunConfig::new(Algorithm::Sta, 10).validate(10).is_ok());
    }

    #[test]
    fn parses_config_text() {
        let cfg = RunConfig::from_str_cfg(
            "# experiment\nalgorithm = exp-ns\nk = 200\nseed = 3\nthreads = 2\ninit = random\nmax_iters = 55\nrecord_rounds = true\n",
        )
        .unwrap();
        assert_eq!(cfg.algorithm, Algorithm::ExpNs);
        assert_eq!(cfg.k, 200);
        assert_eq!(cfg.seed, 3);
        assert_eq!(cfg.threads, 2);
        assert_eq!(cfg.max_iters, 55);
        assert!(cfg.record_rounds);
    }

    #[test]
    fn threads_auto_resolves_to_at_least_one() {
        let cfg = RunConfig::from_str_cfg("threads = auto").unwrap();
        assert_eq!(cfg.threads, AUTO_THREADS);
        assert!(cfg.resolved_threads() >= 1);
        let cfg = RunConfig::new(Algorithm::Sta, 2).threads(AUTO_THREADS);
        assert!(cfg.resolved_threads() >= 1);
        assert_eq!(RunConfig::new(Algorithm::Sta, 2).threads(3).resolved_threads(), 3);
        // an explicit 0 in config text is rejected (only "auto" means auto)
        assert!(RunConfig::from_str_cfg("threads = 0").is_err());
    }

    #[test]
    fn scan_shards_parses_auto_and_counts() {
        use crate::coordinator::sched::AUTO_SCAN_SHARDS;
        let cfg = RunConfig::from_str_cfg("scan_shards = auto").unwrap();
        assert_eq!(cfg.scan_shards, AUTO_SCAN_SHARDS);
        let cfg = RunConfig::from_str_cfg("scan_shards = 32").unwrap();
        assert_eq!(cfg.scan_shards, 32);
        // builder mirrors the file key; the default is auto
        assert_eq!(RunConfig::new(Algorithm::Sta, 2).scan_shards, AUTO_SCAN_SHARDS);
        assert_eq!(RunConfig::new(Algorithm::Sta, 2).scan_shards(8).scan_shards, 8);
        // an explicit 0 in config text is rejected (only "auto" means auto)
        assert!(RunConfig::from_str_cfg("scan_shards = 0").is_err());
        assert!(RunConfig::from_str_cfg("scan_shards = lots").is_err());
    }

    #[test]
    fn batch_knobs_parse_and_validate() {
        let cfg = RunConfig::from_str_cfg("batch_size = 4096\nbatch_growth = 1.5\n").unwrap();
        assert_eq!(cfg.batch_size, Some(4096));
        assert_eq!(cfg.batch_growth, 1.5);
        assert!(cfg.validate(10_000).is_ok());
        // builder mirrors the file keys
        let cfg = RunConfig::new(Algorithm::Sta, 5).batch_size(256).batch_growth(1.0);
        assert_eq!(cfg.batch_size, Some(256));
        assert_eq!(cfg.batch_growth, 1.0);
        // degenerate values are rejected, in text and at validation
        assert!(RunConfig::from_str_cfg("batch_size = 0").is_err());
        assert!(RunConfig::new(Algorithm::Sta, 5)
            .batch_growth(0.5)
            .validate(100)
            .is_err());
        assert!(RunConfig::new(Algorithm::Sta, 5)
            .batch_growth(f64::NAN)
            .validate(100)
            .is_err());
    }

    #[test]
    fn rejects_unknown_keys_and_values() {
        assert!(RunConfig::from_str_cfg("bogus = 1").is_err());
        assert!(RunConfig::from_str_cfg("algorithm = warp-drive").is_err());
        assert!(RunConfig::from_str_cfg("k = banana").is_err());
        assert!(RunConfig::from_str_cfg("no equals sign").is_err());
    }

    #[test]
    fn section_headers_are_validated() {
        // the one recognised section parses (and its keys still apply)
        let cfg = RunConfig::from_str_cfg("[run]\nk = 7\n").unwrap();
        assert_eq!(cfg.k, 7);
        let cfg = RunConfig::from_str_cfg("[ run ]\nseed = 5\n").unwrap();
        assert_eq!(cfg.seed, 5);
        // a typo'd section no longer hides the keys under it — it errors
        let err = RunConfig::from_str_cfg("[rnu]\nk = 7\n").unwrap_err();
        assert!(err.to_string().contains("unknown section"), "{err}");
        // malformed headers error too
        assert!(RunConfig::from_str_cfg("[run\nk = 7\n").is_err());
    }
}
